"""GAScore Pallas RDMA kernel suite + software/hardware engine parity
(4 devices, TPU interpret mode)."""
import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map


def main() -> None:
    from repro.core import collectives
    from repro.core.engine import make_engine
    from repro.kernels import gascore
    from repro.kernels import ref as kref

    N = 4
    mesh = jax.make_mesh((N,), ("node",))

    def run(fn, *args, in_specs=None, out_specs=P("node")):
        if in_specs is None:
            in_specs = tuple(P("node") for _ in args)
        return jax.jit(
            shard_map(fn, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False)
        )(*args)

    x = jnp.arange(4.0 * 8 * 128, dtype=jnp.float32).reshape(4, 8, 128)

    # ring_shift, multiple distances and dtypes
    for k in (1, 2, 3):
        for dt in (jnp.float32, jnp.bfloat16, jnp.int32):
            xx = x.astype(dt)
            y = run(lambda a: gascore.ring_shift(a, k=k, axis="node", n_nodes=N), xx)
            np.testing.assert_array_equal(
                np.asarray(y), kref.ring_shift(np.asarray(xx), k)
            )
    print("ring_shift OK")

    perm = (2, 0, 3, 1)
    y = run(lambda a: gascore.perm_put(a, dst=perm, axis="node", n_nodes=N), x)
    np.testing.assert_array_equal(np.asarray(y), kref.perm_put(np.asarray(x), perm))
    print("perm_put OK")

    # offset_put (AMLong semantics)
    seg = jnp.zeros((4, 16, 128), jnp.float32)
    data = jnp.tile(jnp.arange(4.0)[None, :, None], (4, 1, 128))
    data = data + jnp.arange(4.0)[:, None, None] * 100

    def prog(s, d):
        return gascore.offset_put(
            s[0], d[0], jnp.int32(4), k=1, axis="node", n_nodes=N
        )[None]

    y = np.asarray(run(prog, seg, data))
    for node in range(4):
        np.testing.assert_allclose(y[node, 4:8], np.asarray(data)[(node - 1) % 4])
        np.testing.assert_allclose(y[node, :4], 0)
    print("offset_put OK")

    # fused ring collectives vs oracles
    xl = jnp.arange(4.0 * 2 * 128).reshape(4, 2, 128)
    y = run(lambda a: gascore.ring_all_gather(a[0], axis="node", n_nodes=N)[None],
            xl, in_specs=(P("node"),))
    np.testing.assert_allclose(np.asarray(y), kref.all_gather(np.asarray(xl)))
    print("ring_all_gather OK")

    xf = jnp.arange(4.0 * 8 * 128).reshape(4, 8, 128) / 100.0
    y = run(lambda a: gascore.ring_reduce_scatter(a[0], axis="node", n_nodes=N)[None],
            xf, in_specs=(P("node"),))
    np.testing.assert_allclose(
        np.asarray(y), kref.reduce_scatter(np.asarray(xf)), rtol=1e-6
    )
    print("ring_reduce_scatter OK")

    # ---- engine parity: the paper's software<->hardware migration claim ----
    # "xla,gascore" is the heterogeneous EngineMap — alternating software
    # and hardware ranks in one mesh — and must pass the same parity suite
    # as each homogeneous engine.
    BACKENDS = ("xla", "gascore", "xla,gascore")
    for op in ("all_reduce", "all_to_all", "all_gather", "reduce_scatter"):
        def make_prog(backend, op=op):
            def prog(a):
                e = make_engine(backend, "node", N, interpret=True)
                arg = a[0] if op != "all_gather" else a[0, :2]
                return getattr(e, op)(arg)[None]
            return prog

        outs = [
            np.asarray(run(make_prog(b), xf, in_specs=(P("node"),)))
            for b in BACKENDS
        ]
        for b, o in zip(BACKENDS[1:], outs[1:]):
            np.testing.assert_allclose(
                outs[0], o, rtol=1e-6, err_msg=f"{op} parity vs {b}"
            )
    print("engine parity OK (incl. heterogeneous map)")

    # ring algorithms built on top run on EVERY engine identically,
    # monolithic and segmented/pipelined (the scheduler's bulk tier)
    from repro.core import sched

    def coll_prog(backend):
        def prog(a):
            e = make_engine(backend, "node", N, interpret=True)
            mono = collectives.ring_all_reduce(e, a[0])
            seg = collectives.segmented_ring_all_reduce(
                e, a[0], n_segments=3, depth=2
            )
            planned = sched.all_reduce(e, a[0])
            return mono[None], seg[None], planned[None]
        return prog

    outs = {
        b: tuple(
            np.asarray(y)
            for y in run(coll_prog(b), xf, in_specs=(P("node"),),
                         out_specs=(P("node"),) * 3)
        )
        for b in BACKENDS
    }
    for b in BACKENDS:
        mono, seg, planned = outs[b]
        np.testing.assert_allclose(mono, seg, rtol=1e-6,
                                   err_msg=f"segmented != monolithic on {b}")
        np.testing.assert_allclose(mono, planned, rtol=1e-5,
                                   err_msg=f"planned != monolithic on {b}")
        np.testing.assert_allclose(mono, outs["xla"][0], rtol=1e-6,
                                   err_msg=f"ring parity vs {b}")
    print("collectives-on-engines parity OK (monolithic/segmented/planned)")

    # split-phase primitives + the collectives built on them (Extended API)
    def nb_prog(backend):
        def prog(a):
            e = make_engine(backend, "node", N, interpret=True)
            pending = e.shift_nb(a[0], 1)   # initiate
            local = a[0] * 2.0              # overlapped compute
            shifted = pending.wait()        # sync point
            bc = collectives.broadcast(e, a[0], root=1)
            ex = collectives.exchange(e, a[0])
            return (shifted + 0.0 * local)[None], bc[None], ex[None]
        return prog

    specs3 = (P("node"), P("node"), P("node"))
    sw = run(nb_prog("xla"), xf, in_specs=(P("node"),), out_specs=specs3)
    hw = run(nb_prog("gascore"), xf, in_specs=(P("node"),), out_specs=specs3)
    for name, a, b in zip(("shift_nb", "broadcast", "exchange"), sw, hw):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    # broadcast oracle: every node holds node 1's contribution
    np.testing.assert_allclose(
        np.asarray(sw[1]), np.tile(np.asarray(xf)[1], (N, 1, 1))
    )
    print("split-phase primitives parity OK")

    # ---- vectored get parity: m slices per request/reply pair -------------
    from repro.core import am, gasnet

    mesh_n = jax.make_mesh((N,), ("node",))

    def run_getv(backend):
        ctx_v = gasnet.Context(mesh_n, node_axis="node", backend=backend,
                               interpret=True)

        def prog(node, seg):
            # plain vectored fetch from the left neighbour
            h = node.get_nbv(seg, frm=gasnet.Shift(1),
                             indices=[4, 0, 12], size=3)
            plain = node.sync(h)
            # pred-gated: odd ranks trace the fetch but keep zeros
            gated = node.get_v(seg, frm=gasnet.Shift(2), indices=[8, 2],
                               size=2, pred=(node.my_id % 2) == 0)
            return plain[None], gated[None]

        seg = jnp.arange(4.0 * 16).reshape(4, 16)
        return tuple(
            np.asarray(o)
            for o in ctx_v.spmd(prog, seg, out_specs=(P("node"),) * 2)
        )

    getv = {b: run_getv(b) for b in BACKENDS}
    segv = np.arange(4.0 * 16).reshape(4, 16)
    plain, gated = getv["xla"]
    for node in range(N):
        want = np.stack(
            [segv[(node + 1) % N, i : i + 3] for i in (4, 0, 12)]
        )
        np.testing.assert_allclose(plain[node], want)
        if node % 2 == 0:
            want2 = np.stack([segv[(node + 2) % N, i : i + 2] for i in (8, 2)])
            np.testing.assert_allclose(gated[node], want2)
        else:
            np.testing.assert_allclose(gated[node], 0.0)
    for b in BACKENDS[1:]:
        for name, a, o in zip(("plain", "pred-gated"), getv["xla"], getv[b]):
            np.testing.assert_allclose(
                a, o, err_msg=f"get_nbv parity vs {b}: {name}"
            )
    print("vectored get parity OK (xla/gascore/mixed, incl. pred-gated)")

    # ---- vectored put parity: m writes + command block per transfer -------
    def run_putv(backend):
        ctx_p = gasnet.Context(mesh_n, node_axis="node", backend=backend,
                               interpret=True)

        def prog(node, seg):
            datas = jnp.stack(
                [jnp.full((3,), 1.0 + 10 * node.my_id + j) for j in range(2)]
            )
            h = node.put_nbv(seg, datas, to=gasnet.Shift(1),
                             indices=[1, 9],
                             pred=[True, (node.my_id % 2) == 0])
            seg = node.sync(h)
            return node.put_v(seg, jnp.full((1, 2), 77.0),
                              to=gasnet.Shift(2), indices=[13])

        seg = jnp.zeros((N, 16), jnp.float32)
        return np.asarray(ctx_p.spmd(prog, seg))

    putv = {b: run_putv(b) for b in BACKENDS}
    ref_pv = putv["xla"]
    for node in range(N):
        src = (node - 1) % N
        np.testing.assert_allclose(ref_pv[node, 1:4], 1.0 + 10 * src)
        if src % 2 == 0:
            np.testing.assert_allclose(ref_pv[node, 9:12], 2.0 + 10 * src)
        else:
            np.testing.assert_allclose(ref_pv[node, 9:12], 0.0)
        np.testing.assert_allclose(ref_pv[node, 13:15], 77.0)
    for b in BACKENDS[1:]:
        np.testing.assert_allclose(
            ref_pv, putv[b], err_msg=f"put_nbv parity vs {b}"
        )
    print("vectored put parity OK (xla/gascore/mixed, incl. per-page pred)")

    # ---- AM request/reply parity: software vs hardware vs mixed nodes -----

    def run_request_reply(backend):
        ctx_rr = gasnet.Context(mesh_n, node_axis="node", backend=backend,
                                am_payload_width=4, interpret=True)
        table = ctx_rr.handlers

        def pong(state, payload, args):
            out = dict(state)
            out["ack_payload"] = payload
            out["ack_arg"] = state["ack_arg"] + args[0]
            return out

        pong_id = table.register("pong", pong)

        def ping(state, payload, args):
            out = dict(state)
            out["got"] = state["got"] + args[0]
            reply = am.reply_medium(
                pong_id, payload * 2.0, args=(args[0] + 1,)
            )
            return out, reply

        table.register("ping", ping, replies=True)

        def prog_rr(node, seg):
            me = node.my_id
            state = {
                "got": jnp.zeros((), jnp.int32),
                "ack_arg": jnp.zeros((), jnp.int32),
                "ack_payload": jnp.zeros((4,), jnp.float32),
            }
            h = node.am_call(
                (me + 1) % N, "ping",
                payload=jnp.full((4,), 1.0 + me, jnp.float32),
                args=(me * 5,), ack=lambda st: st["ack_payload"],
            )
            state = node.am_flush(state)
            acked = node.sync(h)
            return (state["got"][None], state["ack_arg"][None],
                    acked[None])

        seg = jnp.zeros((N, 8), jnp.float32)
        return tuple(
            np.asarray(o) for o in ctx_rr.spmd(
                prog_rr, seg, out_specs=(P("node"),) * 3
            )
        )

    rr = {b: run_request_reply(b) for b in BACKENDS}
    got, ack_arg, acked = rr["xla"]
    for node in range(N):
        assert int(got[node]) == ((node - 1) % N) * 5
        assert int(ack_arg[node]) == node * 5 + 1
        np.testing.assert_allclose(acked[node], 2.0 * (1.0 + node))
    for b in BACKENDS[1:]:
        for name, a, o in zip(("got", "ack_arg", "ack_payload"),
                              rr["xla"], rr[b]):
            np.testing.assert_allclose(
                a, o, err_msg=f"request/reply parity vs {b}: {name}"
            )
    print("AM request/reply parity OK (xla/gascore/mixed)")

    # ---- TP-group all-reduce at decode-step payloads ----------------------
    # the tensor-parallel decode group's per-sub-block partial sum:
    # (B, 1, D)-shaped activations, f32 and bf16, planned by the
    # scheduler, bit-for-bit-comparable across software, hardware, and
    # mixed engine maps within dtype tolerance
    def tp_prog(backend, dt):
        def prog(a):
            e = make_engine(backend, "node", N, interpret=True)
            out = sched.all_reduce(e, a[0].astype(dt))
            return out.astype(jnp.float32)[None]
        return prog

    xd = jnp.arange(4.0 * 4 * 1 * 128).reshape(4, 4, 1, 128) / 29.0 - 9.0
    for dt, tol in ((jnp.float32, 1e-6), (jnp.bfloat16, 0.05)):
        want = np.tile(
            np.asarray(xd.astype(dt).astype(jnp.float32)).sum(0),
            (N, 1, 1, 1),
        )
        outs = [
            np.asarray(run(tp_prog(b, dt), xd, in_specs=(P("node"),)))
            for b in BACKENDS
        ]
        for b, o in zip(BACKENDS, outs):
            np.testing.assert_allclose(
                o, want, rtol=tol,
                err_msg=f"TP all-reduce vs numpy on {b} ({dt.__name__})",
            )
            np.testing.assert_allclose(
                o, outs[0], rtol=tol,
                err_msg=f"TP all-reduce engine parity vs {b}",
            )
    print("TP-group decode-payload all-reduce parity OK (f32+bf16)")

    print("GASCORE_SUITE_PASS")


if __name__ == "__main__":
    main()

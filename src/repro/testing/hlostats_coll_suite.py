"""hlostats collective trip-multiplication check (4 devices)."""
import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map


def main() -> None:
    from repro.launch import hlostats

    mesh = jax.make_mesh((4,), ("d",))
    M, T = 256, 10

    def f(x, ws):
        def body(c, w):
            return jax.lax.psum(c @ w, "d"), None

        y, _ = jax.lax.scan(body, x, ws)
        return y

    fn = shard_map(f, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                       check_vma=False)
    comp = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((M, M), jnp.float32),
        jax.ShapeDtypeStruct((T, M, M), jnp.float32),
    ).compile()
    st = hlostats.analyze(comp.as_text())
    expect = T * M * M * 4  # T all-reduces of (M, M) f32 operands
    assert abs(st.collective_bytes - expect) / expect < 0.05, (
        st.collective_bytes, expect)
    per = st.collective_per_type["all-reduce"]
    assert abs(per - expect) / expect < 0.05
    print("HLOSTATS_COLL_PASS")


if __name__ == "__main__":
    main()

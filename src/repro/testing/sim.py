"""Lockstep SPMD simulator: run collective schedules on ONE device.

The ring/tree/recursive-doubling algorithms in ``repro.core.collectives``
are pure functions of ``(engine, local_value)`` whose communication
pattern is *static* — every rank makes the identical sequence of
``shift``/``permute`` calls (SPMD).  That makes them property-testable
without a multi-device mesh: :func:`run_spmd` executes the program once
per rank with a :class:`SimEngine` whose transport reads the values the
*other* ranks sent at the same call index.

Receives at call index c depend only on sends at index c, which depend
only on receives at indices < c, so iterating the whole program to
fixpoint resolves one more call index per sweep; convergence is reached
in at most (#comm calls + 1) sweeps and is verified, not assumed.

This is the single-device analogue of the multi-device suites — used by
the hypothesis property tests (``tests/test_properties.py``) to check,
bit-exactly, that segmented collectives match their monolithic
counterparts for any ``n_segments``/``depth``.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import CommEngine

__all__ = ["SimEngine", "run_spmd"]


class SimEngine(CommEngine):
    """One rank's engine inside :func:`run_spmd` (see module docstring).

    ``my_id`` is the concrete rank; ``shift``/``permute`` record this
    rank's send into the current sweep's mailbox and return what the
    counterpart rank sent at the same call index in the *previous* sweep
    (zeros on the first sweep).
    """

    name = "sim"
    can_permute_partial = True

    def __init__(self, n_nodes: int, rank: int, prev: dict, sends: dict):
        super().__init__(axis="sim", n_nodes=n_nodes)
        self.rank = rank
        self._prev = prev
        self._sends = sends
        self._calls = 0

    def my_id(self) -> jax.Array:
        return jnp.asarray(self.rank, jnp.int32)

    def barrier(self, token=None) -> jax.Array:
        t = jnp.ones((), jnp.int32) if token is None else token
        return t * self.n_nodes

    def _record(self, tag, value) -> int:
        c = self._calls
        self._calls += 1
        slot = self._sends.setdefault(c, {})
        slot[self.rank] = (tag, np.asarray(value))
        return c

    def _recv(self, c: int, src: Optional[int], like: jax.Array) -> jax.Array:
        prev = self._prev.get(c)
        if src is None or prev is None or src not in prev:
            return jnp.zeros_like(like)
        _, val = prev[src]
        return jnp.asarray(val)

    def shift(self, x: jax.Array, k: int = 1) -> jax.Array:
        n = self.n_nodes
        if k % n == 0:
            return x
        c = self._record(("shift", k % n), x)
        return self._recv(c, (self.rank - k) % n, x)

    def permute(self, x: jax.Array, dst: Sequence[int]) -> jax.Array:
        c = self._record(("permute", tuple(dst)), x)
        src = None
        for s, d in enumerate(dst):
            if d is not None and int(d) == self.rank:
                src = s
                break
        return self._recv(c, src, x)

    def all_reduce(self, x: jax.Array) -> jax.Array:
        from repro.core import collectives

        return collectives.ring_all_reduce(self, x)

    def all_gather(self, x: jax.Array) -> jax.Array:
        from repro.core import collectives

        return collectives.ring_all_gather(self, x)

    def reduce_scatter(self, x: jax.Array) -> jax.Array:
        from repro.core import collectives

        return collectives.ring_reduce_scatter(self, x)


def _mailbox_equal(a: dict, b: dict) -> bool:
    if a.keys() != b.keys():
        return False
    for c in a:
        if a[c].keys() != b[c].keys():
            return False
        for r in a[c]:
            (tag_a, va), (tag_b, vb) = a[c][r], b[c][r]
            if tag_a != tag_b or va.shape != vb.shape or va.dtype != vb.dtype:
                return False
            # bitwise, not numeric: NaN payloads (e.g. int bit patterns
            # riding a float carrier) must still reach fixpoint
            if va.tobytes() != vb.tobytes():
                return False
    return True


def run_spmd(
    program: Callable[[CommEngine], object], n_nodes: int, max_sweeps: int = 0
) -> List[object]:
    """Run ``program(engine)`` for every rank, lockstep to fixpoint.

    Returns the per-rank outputs.  Raises if the mailbox has not
    converged after the sweep bound (a data-dependent communication
    pattern, which is not SPMD-static and not supported here).
    """
    prev: dict = {}
    outs: List[object] = []
    sends: dict = {}
    for sweep in range(2):  # bootstrap: discover the call count
        sends = {}
        outs = [
            program(SimEngine(n_nodes, r, prev, sends)) for r in range(n_nodes)
        ]
        if _mailbox_equal(sends, prev):
            return outs
        prev = sends
    bound = max_sweeps or (len(sends) + 2)
    for sweep in range(bound):
        sends = {}
        outs = [
            program(SimEngine(n_nodes, r, prev, sends)) for r in range(n_nodes)
        ]
        if _mailbox_equal(sends, prev):
            return outs
        prev = sends
    raise RuntimeError(
        f"SPMD simulation did not converge in {bound + 2} sweeps; "
        "is the communication pattern data-dependent?"
    )

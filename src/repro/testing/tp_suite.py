"""Tensor-parallel decode suite: planned TP-group all-reduce parity over
mixed engine maps, head-sharded paged decode servers, and TP decode
groups inside the disaggregated cluster (3 devices)."""
import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=3"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map


def main() -> None:
    from repro.configs.registry import SMOKE
    from repro.core import sched
    from repro.core.engine import make_engine
    from repro.launch.serve import PagedServer, Request, TPPagedServer
    from repro.models.build import build_model
    from repro.parallel.ctx import RunCtx
    from repro.serving.disagg import DisaggCluster

    # ---- TP-group all-reduce parity at decode-step payloads ----------------
    # a 2-rank TP group over a ("tp",) mesh — the exact shape and axis the
    # sharded decode step uses — with the planned collective, on pure
    # software, pure hardware, and heterogeneous engine maps.  At 2 ranks
    # every schedule is one exchange-and-add, so parity is BITWISE.
    TP = 2
    mesh = Mesh(np.array(jax.devices()[:TP]), ("tp",))

    def ar_prog(backend, dt):
        def prog(x):
            e = make_engine(backend, "tp", TP, interpret=True)
            return sched.all_reduce(e, x[0].astype(dt))[None]

        return jax.jit(shard_map(prog, mesh=mesh, in_specs=(P("tp"),),
                                 out_specs=P("tp"), check_vma=False))

    for dt in (jnp.float32, jnp.bfloat16):
        x = (jnp.arange(2.0 * 4 * 1 * 128).reshape(2, 4, 1, 128) / 37.0
             - 5.0).astype(jnp.float32)
        want = np.asarray(
            x[0].astype(dt) + x[1].astype(dt), np.float32
        )
        outs = {
            b: np.asarray(ar_prog(b, dt)(x)).astype(np.float32)
            for b in ("xla", "gascore", "xla,gascore")
        }
        for b, o in outs.items():
            np.testing.assert_array_equal(
                o[0], o[1], err_msg=f"all-reduce not replicated on {b}"
            )
            np.testing.assert_array_equal(
                o[0], want, err_msg=f"all-reduce != sum on {b} ({dt})"
            )
    print("TP all-reduce parity OK (xla/gascore/mixed, f32+bf16, bitwise)")

    # ---- head-sharded paged decode server: token parity vs tp=1 ------------
    cfg = SMOKE["qwen3-4b"]
    model = build_model(cfg)
    ctx = RunCtx(mesh=None, remat="none")
    params, _ = model.init(ctx, jax.random.PRNGKey(0))

    rng = np.random.default_rng(11)
    shared = rng.integers(0, cfg.vocab, 9).tolist()
    reqs = []
    for rid in range(5):
        prompt = (shared + rng.integers(0, cfg.vocab, 3).tolist()
                  if rid % 2 == 0 else
                  rng.integers(0, cfg.vocab, int(rng.integers(5, 12))).tolist())
        reqs.append((rid, prompt, int(rng.integers(4, 8))))

    def run_server(server_cls, **kw):
        srv = server_cls(model, ctx, params, batch_size=3, cache_len=32,
                         page_tokens=8, n_pool_pages=14, **kw)
        for rid, prompt, mx in reqs:
            srv.submit(Request(rid=rid, prompt=list(prompt), max_new=mx))
        for _ in range(400):
            if len(srv.finished) == len(reqs):
                break
            srv.step()
        assert len(srv.finished) == len(reqs), "server stalled"
        return {r.rid: list(r.out) for r in srv.finished}

    base = run_server(PagedServer)
    for backend in ("xla", "xla,gascore"):
        toks = run_server(TPPagedServer, tp=2, tp_backend=backend)
        for rid, want in base.items():
            assert toks[rid] == want, (backend, rid, toks[rid], want)
    print("TPPagedServer token parity OK (tp=2, xla + mixed map)")

    # ---- TP decode group inside the disaggregated cluster ------------------
    def run_cluster(**kw):
        cl = DisaggCluster(model, ctx, params, n_prefill=1, decode_batch=2,
                           cache_len=32, page_tokens=8, paged=True, **kw)
        for rid, prompt, mx in reqs:
            cl.submit(Request(rid=rid, prompt=list(prompt), max_new=mx))
        stats = cl.run_until_drained(max_ticks=500)
        return {r.rid: list(r.out) for r in cl.finished}, stats

    cbase, _ = run_cluster(n_decode=1)
    ctp, stats = run_cluster(n_decode=2, tp=2, tp_backend="xla,gascore")
    assert stats["tp"] == 2 and stats["n_decode_groups"] == 1
    assert stats["kv_acked"] == len(reqs)
    for rid, want in cbase.items():
        assert ctp[rid] == want, (rid, ctp[rid], want)
    print("DisaggCluster TP decode group parity OK (1 prefill + tp=2 group)")

    print("TP_SUITE_PASS")


if __name__ == "__main__":
    main()

# NOTE: deliberately does NOT set --xla_force_host_platform_device_count —
# the main pytest process must see 1 CPU device (smoke tests and benches run
# single-device; the dry-run and the multi-device suites manage their own
# device counts in subprocesses).
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


def run_suite(module: str, devices: int, timeout: int = 1200) -> str:
    """Run a repro.testing suite in a subprocess with N host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", module],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
        cwd=ROOT,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"{module} failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout[-4000:]}\n"
            f"--- stderr ---\n{proc.stderr[-4000:]}"
        )
    return proc.stdout


@pytest.fixture(scope="session")
def suite_runner():
    return run_suite

"""AddressSpace registry + sharding sanitizer unit tests (1 device)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.addrspace import AddressSpace, GlobalAddress
from repro.parallel.sharding import sanitize


def _mesh1():
    return jax.make_mesh((1,), ("node",))


def test_register_alloc_read():
    aspace = AddressSpace(_mesh1(), "node")
    spec = aspace.register("seg", (8, 4), jnp.float32)
    assert spec.local_size == 32
    assert spec.global_shape(1) == (1, 8, 4)
    seg = aspace.alloc("seg", init_fn=jnp.ones)
    assert seg.shape == (1, 8, 4)
    got = aspace.read(seg, GlobalAddress(node=0, index=3), length=5)
    np.testing.assert_allclose(np.asarray(got), 1.0)


def test_register_duplicate_rejected():
    aspace = AddressSpace(_mesh1(), "node")
    aspace.register("seg", (4,))
    with pytest.raises(ValueError):
        aspace.register("seg", (4,))


def test_alloc_from_shape_checked():
    aspace = AddressSpace(_mesh1(), "node")
    aspace.register("seg", (4,))
    with pytest.raises(ValueError):
        aspace.alloc_from("seg", jnp.zeros((1, 5)))


def test_bad_node_axis_rejected():
    with pytest.raises(ValueError):
        AddressSpace(_mesh1(), "nope")


def test_sanitize_single_and_tuple_axes():
    mesh = jax.make_mesh((1,), ("model",))
    # size-1 axes always divide
    assert sanitize(P("model", None), (7, 3), mesh) == P("model", None)
    # unknown-dim specs pass through
    assert sanitize(P(None, None), (5,), mesh) == P(None, None)


def test_sanitize_drops_on_fake_wide_mesh():
    # emulate a 4-wide axis via devices reshape is impossible on 1 device;
    # exercise the arithmetic through a stub mesh-like object instead
    class FakeMesh:
        shape = {"model": 4, "data": 2}

    assert sanitize(P("model"), (6,), FakeMesh()) == P(None)
    assert sanitize(P("model"), (8,), FakeMesh()) == P("model")
    assert sanitize(P(("data", "model")), (8,), FakeMesh()) == P(("data", "model"))
    # tuple entry: drop trailing axes until it divides (8 % 8 != 0 -> try
    # ("data",): 6 % 2 == 0)
    assert sanitize(P(("data", "model")), (6,), FakeMesh()) == P(("data",))
    assert sanitize(P(("data", "model")), (3,), FakeMesh()) == P(None)

"""Per-arch smoke tests: reduced config, one fwd/train step on CPU,
shape + finiteness assertions (the assignment's required smoke tier)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, SMOKE, cell_runnable
from repro.models.build import build_model
from repro.parallel.ctx import RunCtx

CTX = RunCtx(mesh=None, remat="none")
KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg):
    batch = {
        "inputs": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
        "targets": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.n_enc_layers:
        batch["frames"] = jax.random.normal(KEY, (B, S, cfg.d_model))
    elif cfg.cross_kv_len:
        batch["xkv"] = jax.random.normal(KEY, (B, cfg.cross_kv_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", list(SMOKE))
def test_smoke_train_step(name):
    cfg = SMOKE[name]
    model = build_model(cfg)
    params, specs = model.init(CTX, KEY)
    # specs tree mirrors params tree
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: not isinstance(x, (dict, list))
    )
    batch = _batch(cfg)

    def loss_fn(p):
        return model.train_loss(p, CTX, batch)

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    gnorm = np.sqrt(
        sum(float((g.astype(jnp.float32) ** 2).sum()) for g in jax.tree.leaves(grads))
    )
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("name", list(SMOKE))
def test_smoke_logits_shape(name):
    cfg = SMOKE[name]
    model = build_model(cfg)
    params, _ = model.init(CTX, KEY)
    logits = jax.jit(lambda p, b: model.train_logits(p, CTX, b))(
        params, _batch(cfg)
    )
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("name", list(SMOKE))
def test_smoke_prefill_decode(name):
    cfg = SMOKE[name]
    model = build_model(cfg)
    params, _ = model.init(CTX, KEY)
    batch = _batch(cfg)
    pre = {k: v for k, v in batch.items() if k in ("inputs", "frames", "xkv")}
    logits, caches = jax.jit(
        lambda p, b: model.prefill(p, CTX, b, cache_len=S + 4)
    )(params, pre)
    assert logits.shape == (B, cfg.vocab)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    pos = jnp.full((B,), S, jnp.int32)
    logits2, caches2 = jax.jit(
        lambda p, t, ps, c: model.decode_step(p, CTX, t, ps, c)
    )(params, tok, pos, caches)
    assert logits2.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    # cache structure is stable across decode steps (scan-compatible)
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


def test_decode_matches_teacher_forcing():
    """Prefill+decode logits == full-sequence forward logits (qwen3)."""
    cfg = SMOKE["qwen3-4b"]
    model = build_model(cfg)
    params, _ = model.init(CTX, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 12), 0, cfg.vocab)
    batch = {"inputs": toks, "targets": toks, "mask": jnp.ones((1, 12))}
    full = np.asarray(model.train_logits(params, CTX, batch))
    # prefill on the first 8, then decode tokens 8..11
    logits, caches = model.prefill(
        params, CTX, {"inputs": toks[:, :8]}, cache_len=16
    )
    np.testing.assert_allclose(full[0, 7], np.asarray(logits)[0], atol=2e-4,
                               rtol=2e-4)
    for t in range(8, 12):
        logits, caches = model.decode_step(
            params, CTX, toks[:, t : t + 1], jnp.asarray([t]), caches
        )
        np.testing.assert_allclose(
            full[0, t], np.asarray(logits)[0], atol=5e-4, rtol=5e-4
        )


def test_decode_matches_teacher_forcing_ssm():
    """Same equivalence for the attention-free arch (state carry path)."""
    cfg = SMOKE["falcon-mamba-7b"]
    model = build_model(cfg)
    params, _ = model.init(CTX, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, 10), 0, cfg.vocab)
    batch = {"inputs": toks, "targets": toks, "mask": jnp.ones((1, 10))}
    full = np.asarray(model.train_logits(params, CTX, batch))
    logits, caches = model.prefill(
        params, CTX, {"inputs": toks[:, :6]}, cache_len=16
    )
    np.testing.assert_allclose(full[0, 5], np.asarray(logits)[0], atol=2e-4,
                               rtol=2e-4)
    for t in range(6, 10):
        logits, caches = model.decode_step(
            params, CTX, toks[:, t : t + 1], jnp.asarray([t]), caches
        )
        np.testing.assert_allclose(
            full[0, t], np.asarray(logits)[0], atol=5e-4, rtol=5e-4
        )


def test_param_counts_full_configs():
    """Published-scale param counts land in the right ballpark."""
    totals = {n: ARCHS[n].param_counts()[0] for n in ARCHS}
    assert 3.8e11 < totals["llama3-405b"] < 4.3e11
    assert 3.0e10 < totals["granite-34b"] < 3.8e10
    assert 3.5e9 < totals["qwen3-4b"] < 4.8e9
    assert 2.3e10 < totals["gemma3-27b"] < 3.0e10
    assert 4.0e11 < totals["arctic-480b"] < 5.5e11
    assert 0.9e12 < totals["kimi-k2-1t-a32b"] < 1.2e12
    assert 6.0e9 < totals["falcon-mamba-7b"] < 8.5e9
    assert 7.5e9 < totals["recurrentgemma-9b"] < 1.1e10
    # active params
    act = {n: ARCHS[n].param_counts()[1] for n in ARCHS}
    assert 2.4e10 < act["kimi-k2-1t-a32b"] < 4.0e10  # ~32B active
    assert act["arctic-480b"] < 4.5e10  # 17B-ish + attn


def test_long_500k_skip_rules():
    runnable = [a for a in ARCHS if cell_runnable(a, "long_500k")[0]]
    assert sorted(runnable) == [
        "falcon-mamba-7b", "gemma3-27b", "recurrentgemma-9b"
    ]
    for a in ARCHS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert cell_runnable(a, s)[0]

"""Checkpoint format: atomicity, async, cleanup, restore."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (8, 16)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                   "c": [jnp.ones((3,)), jnp.zeros((2, 2))]},
    }


def test_save_restore_roundtrip():
    t = _tree()
    with tempfile.TemporaryDirectory() as td:
        h = ckpt.save(td, 5, t, extra={"data_step": 7}, async_=False)
        assert h.done
        assert ckpt.latest_step(td) == 5
        got, extra = ckpt.restore(td, 5, t)
        assert extra["data_step"] == 7
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_consistent_cut():
    t = {"x": jnp.arange(1000.0)}
    with tempfile.TemporaryDirectory() as td:
        h = ckpt.save(td, 1, t, async_=True)
        h.wait()
        got, _ = ckpt.restore(td, 1, t)
        np.testing.assert_array_equal(np.asarray(got["x"]), np.asarray(t["x"]))


def test_cleanup_keeps_last_k():
    t = _tree()
    with tempfile.TemporaryDirectory() as td:
        for s in (1, 2, 3, 4):
            ckpt.save(td, s, t, async_=False)
        ckpt.cleanup(td, keep_last=2)
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(td) if d.startswith("step_")
        )
        assert steps == [3, 4]
        assert ckpt.latest_step(td) == 4


def test_restore_into_structs():
    """Restore works with ShapeDtypeStruct targets (no prior allocation)."""
    t = _tree()
    with tempfile.TemporaryDirectory() as td:
        ckpt.save(td, 2, t, async_=False)
        structs = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t
        )
        got, _ = ckpt.restore(td, 2, structs)
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

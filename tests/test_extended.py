"""Extended API (split-phase non-blocking RMA) handle mechanics.

Single-device fast checks: handle lifecycle, FIFO sync_all, blocking ==
nb+sync equivalence on a 1-node mesh.  Multi-node semantics and xla/gascore
engine parity live in the subprocess suites (testing/gas_suite.py,
testing/gascore_suite.py via tests/test_multidev.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import extended, gasnet
from repro.core.engine import Pending, XlaEngine


def make_ctx():
    mesh = jax.make_mesh((1,), ("node",))
    return gasnet.Context(mesh, node_axis="node", backend="xla")


def test_pending_wait_returns_value():
    p = Pending(jnp.arange(4.0))
    np.testing.assert_allclose(np.asarray(p.wait()), np.arange(4.0))
    assert p.ready()


def test_put_handle_lands_payload_at_offset():
    local = jnp.zeros((8,), jnp.float32)
    h = extended.PutHandle(
        local,
        moved=jnp.array([1.0, 2.0]),
        midx=jnp.int32(3),
        received=jnp.array(True),
        restore=lambda x: x,
    )
    out = np.asarray(h.complete())
    np.testing.assert_allclose(out, [0, 0, 0, 1, 2, 0, 0, 0])


def test_put_handle_without_arrival_is_noop():
    local = jnp.ones((4,), jnp.float32)
    h = extended.PutHandle(
        local,
        moved=jnp.array([9.0]),
        midx=jnp.int32(0),
        received=jnp.array(False),  # no sender targeted this node
        restore=lambda x: x,
    )
    np.testing.assert_allclose(np.asarray(h.complete()), 1.0)


def test_handle_syncs_exactly_once():
    h = extended.GetHandle(jnp.zeros((2,)))
    h.complete()
    with pytest.raises(RuntimeError, match="already synced"):
        h.complete()


def test_node_sync_all_is_fifo():
    ctx = make_ctx()
    aspace = ctx.address_space()
    aspace.register("buf", (8,), jnp.float32)
    seg = aspace.alloc("buf", init_fn=jnp.ones)

    def prog(node, seg):
        node.put_nb(seg, jnp.full((2,), 5.0), index=0)
        node.get_nb(seg, index=4, size=2)
        seg2, got = node.sync_all()
        assert not node._outstanding
        return seg2, got[None]

    seg2, got = ctx.spmd(prog, seg, out_specs=(P("node"), P("node")))
    np.testing.assert_allclose(np.asarray(seg2)[0, :2], 5.0)
    np.testing.assert_allclose(np.asarray(got)[0], 1.0)


def test_multiple_outstanding_puts_compose():
    """GASNet permits several puts in flight: syncing them FIFO must land
    every write, not just the last-synced one."""
    ctx = make_ctx()
    aspace = ctx.address_space()
    aspace.register("buf", (8,), jnp.float32)
    seg = aspace.alloc("buf")

    def prog(node, seg):
        h1 = node.put_nb(seg, jnp.full((2,), 1.0), index=0)
        h2 = node.put_nb(seg, jnp.full((2,), 2.0), index=4)
        seg = node.sync(h1)
        seg = node.sync(h2)
        return seg

    out = np.asarray(ctx.spmd(prog, seg))[0]
    np.testing.assert_allclose(out, [1, 1, 0, 0, 2, 2, 0, 0])

    def prog_all(node, seg):
        node.put_nb(seg, jnp.full((2,), 3.0), index=0)
        node.put_nb(seg, jnp.full((2,), 4.0), index=2)
        node.put_nb(seg, jnp.full((2,), 5.0), index=4)
        s1, s2, s3 = node.sync_all()
        return s3

    out = np.asarray(ctx.spmd(prog_all, seg))[0]
    np.testing.assert_allclose(out, [3, 3, 4, 4, 5, 5, 0, 0])


def test_sequential_blocking_puts_stay_independent():
    """Two blocking puts issued from the SAME input array are separate
    one-sided writes to separate result values (seed semantics), not a
    chain — only *outstanding* nb puts compose."""
    ctx = make_ctx()
    aspace = ctx.address_space()
    aspace.register("buf", (4,), jnp.float32)
    seg = aspace.alloc("buf")

    def prog(node, seg):
        a = node.put(seg, jnp.full((2,), 1.0), index=0)
        b = node.put(seg, jnp.full((2,), 2.0), index=2)
        return a, b

    a, b = ctx.spmd(prog, seg, out_specs=(P("node"), P("node")))
    np.testing.assert_allclose(np.asarray(a)[0], [1, 1, 0, 0])
    np.testing.assert_allclose(np.asarray(b)[0], [0, 0, 2, 2])


def test_blocking_equals_nb_plus_sync():
    ctx = make_ctx()
    aspace = ctx.address_space()
    aspace.register("buf", (8,), jnp.float32)
    seg = aspace.alloc("buf")

    def prog_blocking(node, seg):
        return node.put(seg, jnp.arange(3.0), index=2)

    def prog_nb(node, seg):
        h = node.put_nb(seg, jnp.arange(3.0), index=2)
        _ = jnp.ones((4, 4)) @ jnp.ones((4, 4))  # overlapped compute
        return node.sync(h)

    a = np.asarray(ctx.spmd(prog_blocking, seg))
    b = np.asarray(ctx.spmd(prog_nb, seg))
    np.testing.assert_allclose(a, b)


def test_try_sync_reports_done():
    ctx = make_ctx()
    aspace = ctx.address_space()
    aspace.register("buf", (4,), jnp.float32)
    seg = aspace.alloc("buf", init_fn=jnp.ones)

    def prog(node, seg):
        h = node.get_nb(seg, index=0, size=2)
        done, val = node.try_sync(h)
        assert done
        return val[None]

    out = ctx.spmd(prog, seg, out_specs=P("node"))
    np.testing.assert_allclose(np.asarray(out)[0], 1.0)


def test_gpipe_runs_with_explicit_engine():
    from repro.parallel.pipeline import gpipe
    from repro.compat import shard_map

    mesh = jax.make_mesh((1,), ("pod",))
    x = jnp.arange(4.0 * 2 * 3).reshape(4, 2, 3)  # (M, mb, d)
    w = jnp.eye(3) * 2.0

    def stage(p, xb):
        return xb @ p

    def fn(p, xm):
        eng = XlaEngine("pod", 1)
        return gpipe(stage, p, xm, axis="pod", n_stages=1, engine=eng)

    out = jax.jit(
        shard_map(fn, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                  check_vma=False)
    )(w, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x) * 2.0)

"""Fault-tolerance control-plane logic (injectable clock, no devices)."""

from repro.runtime.ft import (
    HeartbeatMonitor,
    StragglerTracker,
    elastic_plan,
)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_heartbeat_failure_detection():
    clk = FakeClock()
    mon = HeartbeatMonitor(range(4), timeout_s=5.0, clock=clk)
    clk.t = 3.0
    for n in (0, 1, 2):
        mon.beat(n)
    clk.t = 7.0
    assert mon.check() == [3]  # node 3 silent since t=0
    assert mon.failed == [3]
    assert mon.alive == [0, 1, 2]
    # failed stays failed even if a stale beat arrives
    mon.beat(3)
    clk.t = 8.0
    assert mon.check() == []
    assert mon.failed == [3]
    # rejoin via admit
    mon.admit(3)
    assert mon.alive == [0, 1, 2, 3]


def test_heartbeat_monotone_multiple():
    clk = FakeClock()
    mon = HeartbeatMonitor(range(6), timeout_s=1.0, clock=clk)
    clk.t = 2.0
    mon.beat(0)
    mon.beat(5)
    assert sorted(mon.check()) == [1, 2, 3, 4]


def test_straggler_quarantine_after_patience():
    tr = StragglerTracker(range(4), alpha=1.0, threshold=1.5, patience=2)
    for step in range(3):
        for n in range(3):
            tr.record(n, 1.0)
        tr.record(3, 3.0)  # 3x median
        decisions = tr.assess()
        flagged = {d.node_id: d.action for d in decisions}
        assert 3 in flagged
        if step == 0:
            assert flagged[3] == "observe"
        else:
            assert flagged[3] == "quarantine"


def test_straggler_recovers():
    tr = StragglerTracker(range(3), alpha=1.0, threshold=1.5, patience=2)
    tr.record(0, 1.0)
    tr.record(1, 1.0)
    tr.record(2, 5.0)
    assert tr.assess()[0].action == "observe"
    tr.record(2, 1.0)  # back to normal -> strikes reset
    assert tr.assess() == []
    assert tr.strikes[2] == 0


def test_elastic_plan_shrinks_dp_first():
    assert elastic_plan(512, 16, prefer_pods=2) == (2, 16, 16)
    # losing one node: collapsing pods preserves more DP groups (496 > 480)
    assert elastic_plan(511, 16, prefer_pods=2) == (1, 31, 16)
    # equal usable nodes -> prefer keeping the pod structure
    assert elastic_plan(260, 16, prefer_pods=2) == (2, 8, 16)
    assert elastic_plan(255, 16, prefer_pods=2) == (1, 15, 16)
    assert elastic_plan(15, 16) is None

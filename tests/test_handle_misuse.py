"""Split-phase handle misuse: every double-wait must raise, loudly.

GASNet's ``wait_syncnb`` on an already-synced handle is undefined
behaviour on the wire; here it is a defined error
(:class:`~repro.core.extended.AlreadyWaitedError`) so a lost handle or
a duplicated sync in host scheduling code fails the run instead of
silently re-applying (or dropping) a transfer.  Parameterised over the
software (``xla``) and hardware (``gascore`` interpret-mode) engines on
a 1-node mesh — the handle lifecycle is engine-independent and must
stay that way.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import gasnet
from repro.core.extended import AlreadyWaitedError

ENGINES = ("xla", "gascore")


def make_ctx(backend):
    mesh = jax.make_mesh((1,), ("node",))
    return gasnet.Context(mesh, node_axis="node", backend=backend)


def make_seg(ctx, n_el=16):
    aspace = ctx.address_space()
    aspace.register("buf", (n_el,), jnp.float32)
    return aspace.alloc("buf", init_fn=jnp.ones)


@pytest.mark.parametrize("backend", ENGINES)
def test_put_sync_after_try_sync_raises(backend):
    ctx = make_ctx(backend)
    seg = make_seg(ctx)

    def prog(node, seg):
        h = node.put_nb(seg, jnp.full((2,), 5.0), index=0)
        done, seg2 = node.try_sync(h)
        assert done  # static schedule: the poll always completes
        with pytest.raises(AlreadyWaitedError, match="already synced"):
            node.sync(h)
        return seg2

    seg2 = ctx.spmd(prog, seg, out_specs=P("node"))
    np.testing.assert_allclose(np.asarray(seg2)[0, :2], 5.0)


@pytest.mark.parametrize("backend", ENGINES)
def test_get_sync_after_try_sync_raises(backend):
    ctx = make_ctx(backend)
    seg = make_seg(ctx)

    def prog(node, seg):
        h = node.get_nb(seg, index=4, size=2)
        done, got = node.try_sync(h)
        assert done
        with pytest.raises(AlreadyWaitedError, match="already synced"):
            node.sync(h)
        return got[None]

    got = ctx.spmd(prog, seg, out_specs=P("node"))
    np.testing.assert_allclose(np.asarray(got)[0], 1.0)


@pytest.mark.parametrize("backend", ENGINES)
def test_double_sync_all_harmless_but_drained_handle_raises(backend):
    """``sync_all`` twice is legal (the second is a no-op over an empty
    outstanding list) — but manually syncing a handle the first
    ``sync_all`` already completed is the double-wait error."""
    ctx = make_ctx(backend)
    seg = make_seg(ctx)

    def prog(node, seg):
        h_put = node.put_nb(seg, jnp.full((2,), 3.0), index=0)
        node.get_nb(seg, index=8, size=2)
        first = node.sync_all()
        assert len(first) == 2
        assert node.sync_all() == []  # idempotent on an empty list
        with pytest.raises(AlreadyWaitedError, match="already synced"):
            node.sync(h_put)
        return first[0]

    seg2 = ctx.spmd(prog, seg, out_specs=P("node"))
    np.testing.assert_allclose(np.asarray(seg2)[0, :2], 3.0)


@pytest.mark.parametrize("backend", ENGINES)
def test_putv_handle_double_sync_raises(backend):
    ctx = make_ctx(backend)
    seg = make_seg(ctx)

    def prog(node, seg):
        h = node.put_nbv(
            seg, jnp.arange(4.0).reshape(2, 2), indices=[0, 8]
        )
        seg2 = node.sync(h)
        with pytest.raises(AlreadyWaitedError, match="already synced"):
            node.sync(h)
        return seg2

    seg2 = ctx.spmd(prog, seg, out_specs=P("node"))
    np.testing.assert_allclose(np.asarray(seg2)[0, :2], [0.0, 1.0])
    np.testing.assert_allclose(np.asarray(seg2)[0, 8:10], [2.0, 3.0])


@pytest.mark.parametrize("backend", ENGINES)
def test_getv_handle_double_sync_raises(backend):
    ctx = make_ctx(backend)
    seg = make_seg(ctx)

    def prog(node, seg):
        h = node.get_nbv(seg, indices=[0, 4], size=2)
        got = node.sync(h)
        with pytest.raises(AlreadyWaitedError, match="already synced"):
            node.sync(h)
        return got[None]

    got = ctx.spmd(prog, seg, out_specs=P("node"))
    assert np.asarray(got).shape == (1, 2, 2)
    np.testing.assert_allclose(np.asarray(got)[0], 1.0)

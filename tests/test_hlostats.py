"""Calibration of the trip-count-aware HLO analyzer (roofline inputs)."""
import jax
import jax.numpy as jnp

from repro.launch import hlostats

M = 128


def _compile(fn, *structs):
    return jax.jit(fn).lower(*structs).compile()


def test_xla_cost_analysis_undercounts_loops():
    """Documents WHY hlostats exists: XLA counts while bodies once."""

    def f(x, ws):
        def body(c, w):
            return c @ w, None

        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((M, M), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, M, M), jnp.float32)
    comp = _compile(f, x, ws)
    ca = comp.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    assert ca["flops"] < 2 * 2 * M**3  # ~1 matmul counted, not 10


def test_hlostats_scan_flops_exact():
    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None

        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((M, M), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, M, M), jnp.float32)
    st = hlostats.analyze(_compile(f, x, ws).as_text())
    expected = 10 * 2 * M**3
    assert abs(st.flops - expected) / expected < 0.02  # tanh adds ~0.2%
    assert not st.unresolved_whiles
    assert 10 in st.while_trips.values()


def test_hlostats_grad_scan_flops():
    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None

        y, _ = jax.lax.scan(body, x, ws)
        return (y**2).sum()

    x = jax.ShapeDtypeStruct((M, M), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, M, M), jnp.float32)
    st = hlostats.analyze(_compile(jax.grad(f, argnums=1), x, ws).as_text())
    expected = 3 * 10 * 2 * M**3  # fwd + 2 bwd matmuls per layer
    assert abs(st.flops - expected) / expected < 0.05
    assert not st.unresolved_whiles


def test_hlostats_nested_scan():
    def f(x, ws):
        def outer(c, w):
            def inner(ci, _):
                return jnp.tanh(ci @ w), None

            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None

        y, _ = jax.lax.scan(outer, x, ws)
        return y

    x = jax.ShapeDtypeStruct((M, M), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, M, M), jnp.float32)
    st = hlostats.analyze(_compile(f, x, ws).as_text())
    expected = 5 * 3 * 2 * M**3
    assert abs(st.flops - expected) / expected < 0.02


def test_hlostats_dot_bytes_counted():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    st = hlostats.analyze(_compile(f, a, b).as_text())
    assert st.flops == 2 * 256**3
    assert st.bytes >= 3 * 256 * 256 * 4  # two reads + one write

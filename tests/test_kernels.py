"""Per-kernel allclose sweeps: Pallas (interpret) vs pure-jnp oracles.

Shape × dtype sweeps per the deliverable: every kernel is validated against
``repro.kernels.ref`` on CPU via TPU-interpret mode.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _rand(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


# --------------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------------- #
FA_CASES = [
    # (B, Hq, Hkv, S, D, causal, window, dtype)
    (2, 4, 2, 256, 64, True, None, jnp.float32),
    (1, 4, 4, 128, 128, True, None, jnp.float32),
    (2, 8, 2, 256, 64, True, 64, jnp.float32),
    (1, 2, 1, 128, 64, False, None, jnp.float32),
    (1, 4, 1, 256, 128, True, None, jnp.bfloat16),
    (1, 2, 2, 128, 64, True, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("case", FA_CASES, ids=[str(c) for c in FA_CASES])
def test_flash_attention_vs_oracle(case):
    B, Hq, Hkv, S, D, causal, window, dtype = case
    q = _rand((B, Hq, S, D), dtype)
    k = _rand((B, Hkv, S, D), dtype)
    v = _rand((B, Hkv, S, D), dtype)
    got = ops.attention(q, k, v, causal=causal, window=window, impl="pallas")
    want = ref.attention(q, k, v, causal=causal, window=window)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=atol, rtol=atol,
    )


def test_flash_attention_block_shapes():
    q = _rand((1, 2, 512, 64))
    k = _rand((1, 2, 512, 64))
    v = _rand((1, 2, 512, 64))
    want = ref.attention(q, k, v, causal=True)
    for bq, bk in [(128, 128), (256, 128), (128, 256), (512, 512)]:
        got = ops.attention(
            q, k, v, causal=True, impl="pallas", block_q=bq, block_k=bk
        )
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5
        )


# --------------------------------------------------------------------------- #
# paged attention (decode through a page table)
# --------------------------------------------------------------------------- #
PA_CASES = [
    # (B, Hq, Hkv, D, page_tokens, n_pages, dtype)
    (2, 4, 2, 16, 4, 3, jnp.float32),
    (1, 2, 2, 8, 8, 2, jnp.float32),
    (3, 8, 2, 32, 16, 4, jnp.float32),
    (2, 4, 1, 64, 8, 4, jnp.bfloat16),
]


@pytest.mark.parametrize("case", PA_CASES, ids=[str(c) for c in PA_CASES])
def test_paged_attention_vs_oracle(case):
    B, Hq, Hkv, D, T, NP, dtype = case
    P = B * NP + 2  # pool bigger than any one request's table
    q = _rand((B, Hq, D), dtype)
    kp = _rand((P, T, Hkv, D), dtype)
    vp = _rand((P, T, Hkv, D), dtype)
    # scattered physical placement: tables index the pool arbitrarily
    table = jnp.asarray(
        RNG.permutation(P)[: B * NP].reshape(B, NP), jnp.int32
    )
    lengths = jnp.asarray(RNG.integers(0, NP * T + 1, size=(B,)), jnp.int32)
    got = ops.paged_attention(q, kp, vp, table, lengths, impl="pallas")
    want = ref.paged_attention(q, kp, vp, table, lengths)
    atol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=atol, rtol=atol,
    )


def test_paged_attention_matches_dense_attention():
    """Contiguous identity table + full length == dense decode attention."""
    B, Hq, Hkv, D, T, NP = 2, 4, 2, 16, 4, 4
    S = NP * T
    q = _rand((B, Hq, D))
    kd = _rand((B, Hkv, S, D))
    vd = _rand((B, Hkv, S, D))
    # pack the dense cache into per-request contiguous pages
    kp = jnp.moveaxis(kd, 1, 2).reshape(B * NP, T, Hkv, D)
    vp = jnp.moveaxis(vd, 1, 2).reshape(B * NP, T, Hkv, D)
    table = jnp.arange(B * NP, dtype=jnp.int32).reshape(B, NP)
    lengths = jnp.full((B,), S, jnp.int32)
    got = ops.paged_attention(q, kp, vp, table, lengths, impl="pallas")
    # dense oracle: non-causal single query over the whole cache
    want = ref.attention(q[:, :, None, :], kd, vd, causal=False)[:, :, 0]
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5
    )


def test_paged_attention_masks_padded_pages():
    """Padded table entries (aliased to live pages) must not leak."""
    B, Hq, Hkv, D, T, NP = 1, 2, 1, 8, 4, 3
    q = _rand((B, Hq, D))
    kp = _rand((4, T, Hkv, D))
    vp = _rand((4, T, Hkv, D))
    lengths = jnp.asarray([5], jnp.int32)  # 2 live pages (partial second)
    base = jnp.asarray([[0, 1, 2]], jnp.int32)
    alias = jnp.asarray([[0, 1, 0]], jnp.int32)  # padded entry aliases page 0
    for impl in ("ref", "pallas"):
        a = ops.paged_attention(q, kp, vp, base, lengths, impl=impl)
        b = ops.paged_attention(q, kp, vp, alias, lengths, impl=impl)
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-6, rtol=1e-6
        )


# adversarial parity sweep for the batch-blocked kernel: GQA ratios, page
# counts that don't divide the DMA block, boundary lengths, and garbage in
# masked slots — every case checked against the jnp oracle
PA_ADV_CASES = [
    # (B, Hq, Hkv, NP, pages_per_block, block_b)
    (1, 1, 1, 1, 4, 4),     # B=1, MHA ratio 1, single page
    (2, 4, 1, 5, 4, 4),     # GQA 4, NP not a multiple of pages_per_block
    (3, 8, 1, 3, 2, 2),     # GQA 8, odd page count, B not multiple of blk_b
    (5, 8, 2, 7, 4, 2),     # odd B, NP=7 vs ppb=4 (partial last burst)
    (2, 8, 8, 2, 1, 1),     # ratio 1 with many heads, degenerate blocking
]


@pytest.mark.parametrize(
    "case", PA_ADV_CASES, ids=[str(c) for c in PA_ADV_CASES]
)
def test_paged_attention_adversarial_parity(case):
    B, Hq, Hkv, NP, ppb, bb = case
    D, T = 16, 4
    P = B * NP + 2
    q = _rand((B, Hq, D))
    kp = _rand((P, T, Hkv, D))
    vp = _rand((P, T, Hkv, D))
    table = jnp.asarray(
        RNG.permutation(P)[: B * NP].reshape(B, NP), jnp.int32
    )
    # boundary lengths: 0, 1, exactly one page, exact page multiple, full
    edge = [0, 1, T, min(2 * T, NP * T), NP * T]
    lengths = jnp.asarray((edge * ((B + 4) // 5))[:B], jnp.int32)
    got = ops.paged_attention(
        q, kp, vp, table, lengths, impl="pallas",
        pages_per_block=ppb, block_b=bb,
    )
    want = ref.paged_attention(q, kp, vp, table, lengths)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=2e-6, rtol=2e-6
    )
    # a row at length 0 attends to nothing: output must be exactly zero
    zero_rows = np.asarray(lengths) == 0
    if zero_rows.any():
        assert (np.asarray(got)[zero_rows] == 0).all()


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_paged_attention_nan_in_masked_slots(impl):
    """NaN/Inf garbage behind ``lengths`` and in padded table slots must
    never reach the output (0 * NaN = NaN, so masking scores alone is not
    enough — the kernel has to zero V at masked positions too)."""
    B, Hq, Hkv, D, T, NP = 2, 4, 2, 16, 4, 3
    P = 8
    q = _rand((B, Hq, D))
    kp = np.asarray(_rand((P, T, Hkv, D))).copy()
    vp = np.asarray(_rand((P, T, Hkv, D))).copy()
    table = np.asarray([[0, 1, 2], [3, 4, 5]], np.int32)
    lengths = jnp.asarray([5, 9], jnp.int32)
    # poison everything past the live prefix: tail of the partial page and
    # the fully-dead pages (6, 7 stay clean as the pool's free pages)
    kp[2], vp[2] = np.nan, np.inf     # dead page of row 0
    kp[1, 1:], vp[1, 1:] = np.inf, np.nan  # masked tail of row 0's page 1
    kp[5, 1:], vp[5, 1:] = np.nan, np.nan  # masked tail of row 1's page 5
    clean = ops.paged_attention(
        jnp.asarray(q),
        jnp.asarray(np.nan_to_num(kp, nan=0.0, posinf=0.0, neginf=0.0)),
        jnp.asarray(np.nan_to_num(vp, nan=0.0, posinf=0.0, neginf=0.0)),
        jnp.asarray(table), lengths, impl=impl,
    )
    got = ops.paged_attention(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
        jnp.asarray(table), lengths, impl=impl,
    )
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(clean), atol=1e-6, rtol=1e-6
    )


# --------------------------------------------------------------------------- #
# MoE router
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize(
    "T,E,K,C,bt",
    [(512, 16, 2, 80, 128), (256, 8, 1, 64, 256), (512, 64, 8, 72, 64),
     (256, 128, 2, 8, 128)],
)
def test_moe_router_vs_oracle(T, E, K, C, bt):
    logits = _rand((T, E))
    ge, gs, gw, gk = ops.moe_router(
        logits, k=K, capacity=C, impl="pallas", block_t=bt
    )
    re_, rs_, rw_, rk_ = ref.route_topk(logits, k=K, capacity=C)
    np.testing.assert_array_equal(np.asarray(ge), np.asarray(re_))
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(rs_))
    np.testing.assert_array_equal(np.asarray(gk), np.asarray(rk_))
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw_), atol=1e-6)


# --------------------------------------------------------------------------- #
# scans
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize(
    "B,S,Di,N,bd,bs",
    [(2, 128, 256, 16, 128, 32), (1, 64, 512, 16, 512, 64),
     (2, 96, 128, 8, 64, 32)],
)
def test_selective_scan_vs_oracle(B, S, Di, N, bd, bs):
    x = _rand((B, S, Di))
    dt = jnp.asarray(RNG.uniform(1e-3, 1e-1, size=(B, S, Di)), jnp.float32)
    a = -jnp.asarray(RNG.uniform(0.5, 2.0, size=(Di, N)), jnp.float32)
    b = _rand((B, S, N))
    c = _rand((B, S, N))
    d = _rand((Di,))
    got = ops.selective_scan(x, dt, a, b, c, d, impl="pallas",
                             block_d=bd, block_s=bs)
    want = ref.selective_scan(x, dt, a, b, c, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize(
    "B,S,D,dtype",
    [(2, 128, 256, jnp.float32), (1, 64, 512, jnp.float32),
     (2, 128, 256, jnp.bfloat16)],
)
def test_gated_linear_scan_vs_oracle(B, S, D, dtype):
    a = jnp.asarray(RNG.uniform(0.1, 0.99, size=(B, S, D)), dtype)
    b = _rand((B, S, D), dtype)
    got = ops.gated_linear_scan(a, b, impl="pallas", block_d=128, block_s=32)
    want = ref.gated_linear_scan(a, b)
    atol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=atol, rtol=atol,
    )


# --------------------------------------------------------------------------- #
# dispatch/combine roundtrip
# --------------------------------------------------------------------------- #
def test_moe_dispatch_combine_conservation():
    T, E, K, D = 128, 8, 2, 32
    logits = _rand((T, E))
    tokens = _rand((T, D))
    e, s, w, keep = ref.route_topk(logits, k=K, capacity=T)  # no drops
    buf = ref.moe_dispatch(tokens, e, s, keep, n_experts=E, capacity=T)
    out = ref.moe_combine(buf, e, s, w, keep)
    # identity experts + weights summing to 1 -> combine(dispatch(x)) == x
    np.testing.assert_allclose(np.asarray(out), np.asarray(tokens),
                               atol=1e-5, rtol=1e-5)


# --------------------------------------------------------------------------- #
# chunked (associative) scans — the §Perf iteration variants
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("chunk", [16, 32, 128])
def test_selective_scan_chunked_vs_oracle(chunk):
    B, S, Di, N = 2, 100, 64, 8
    x = _rand((B, S, Di))
    dt = jnp.asarray(RNG.uniform(1e-3, 0.3, size=(B, S, Di)), jnp.float32)
    a = -jnp.asarray(RNG.uniform(0.5, 8.0, size=(Di, N)), jnp.float32)
    b = _rand((B, S, N))
    c = _rand((B, S, N))
    d = _rand((Di,))
    got = ref.selective_scan_chunked(x, dt, a, b, c, d, chunk=chunk)
    want = ref.selective_scan(x, dt, a, b, c, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("chunk", [16, 64])
def test_gated_linear_scan_chunked_vs_oracle(chunk):
    B, S, D = 2, 90, 48
    a = jnp.asarray(RNG.uniform(0.05, 0.99, size=(B, S, D)), jnp.float32)
    b = _rand((B, S, D))
    got = ref.gated_linear_scan_chunked(a, b, chunk=chunk)
    want = ref.gated_linear_scan(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


def test_chunked_scan_gradients_match():
    """The perf variant must be a drop-in for training (same gradients)."""
    B, S, Di, N = 1, 64, 32, 4
    x = _rand((B, S, Di))
    dt = jnp.asarray(RNG.uniform(1e-3, 0.2, size=(B, S, Di)), jnp.float32)
    a = -jnp.asarray(RNG.uniform(0.5, 4.0, size=(Di, N)), jnp.float32)
    b = _rand((B, S, N))
    c = _rand((B, S, N))
    d = _rand((Di,))

    g1 = jax.grad(lambda xx: (ref.selective_scan(xx, dt, a, b, c, d) ** 2).sum())(x)
    g2 = jax.grad(
        lambda xx: (ref.selective_scan_chunked(xx, dt, a, b, c, d, chunk=16) ** 2).sum()
    )(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=5e-4,
                               rtol=5e-4)


# --------------------------------------------------------------------------- #
# flash attention BACKWARD kernels (custom VJP) vs jax.grad of the oracle
# --------------------------------------------------------------------------- #
FA_BWD_CASES = [
    (1, 2, 1, 128, 64, True, None),
    (2, 4, 2, 128, 64, True, None),
    (1, 2, 2, 128, 64, False, None),
    (1, 4, 1, 128, 64, True, 64),
]


@pytest.mark.parametrize("case", FA_BWD_CASES, ids=[str(c) for c in FA_BWD_CASES])
def test_flash_attention_backward_vs_oracle(case):
    from repro.kernels.flash_attention_bwd import flash_attention_vjp

    B, Hq, Hkv, S, D, causal, window = case
    q = _rand((B, Hq, S, D))
    k = _rand((B, Hkv, S, D))
    v = _rand((B, Hkv, S, D))

    def loss_kernel(q, k, v):
        return (flash_attention_vjp(q, k, v, causal, window, None, 64, 64,
                                    True) ** 2).sum()

    def loss_ref(q, k, v):
        return (ref.attention(q, k, v, causal=causal, window=window) ** 2).sum()

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-4)


def test_flash_attention_lse_output():
    from repro.kernels.flash_attention import flash_attention

    q = _rand((1, 2, 128, 64))
    k = _rand((1, 2, 128, 64))
    v = _rand((1, 2, 128, 64))
    out, lse = flash_attention(q, k, v, causal=True, return_lse=True)
    # lse == logsumexp of the masked scores
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / (64 ** 0.5)
    mask = jnp.tril(jnp.ones((128, 128), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    want = jax.nn.logsumexp(s, axis=-1)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(want),
                               atol=2e-4, rtol=2e-4)

"""Multi-device suites (subprocesses with forced host device counts).

The main pytest process keeps 1 CPU device; each suite sets its own
XLA_FLAGS before importing jax.  See src/repro/testing/*.
"""
import pytest


@pytest.mark.slow
def test_gas_suite(suite_runner):
    out = suite_runner("repro.testing.gas_suite", devices=8)
    assert "GAS_SUITE_PASS" in out


@pytest.mark.slow
def test_gascore_suite(suite_runner):
    out = suite_runner("repro.testing.gascore_suite", devices=4)
    assert "GASCORE_SUITE_PASS" in out


@pytest.mark.slow
def test_tp_suite(suite_runner):
    out = suite_runner("repro.testing.tp_suite", devices=3)
    assert "TP_SUITE_PASS" in out


@pytest.mark.slow
def test_dist_suite(suite_runner):
    out = suite_runner("repro.testing.dist_suite", devices=8, timeout=1800)
    assert "DIST_SUITE_PASS" in out


@pytest.mark.slow
def test_hlostats_collective_trip_multiplication(suite_runner):
    """Collective bytes inside scanned loops are multiplied by trip count —
    the property the roofline collective term depends on."""
    out = suite_runner("repro.testing.hlostats_coll_suite", devices=4)
    assert "HLOSTATS_COLL_PASS" in out

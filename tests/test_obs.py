"""Unit tests for the observability stack: tracer, typed metrics,
Chrome-trace export + validation, flight recorder, and the measured
cost-model refit (``EngineCost.fit_from_trace``).

These are pure host-side tests — no mesh, no jit — exercising exactly
the invariants the serving instrumentation relies on: deterministic
tick-clock ordering, byte-parity between RMA spans and counters,
counter-only reset, and the never-synced-handle detection that turns a
leaked split-phase op into a validation failure.
"""
import time

import pytest

from repro.core.sched import DEFAULT_COSTS, EngineCost
from repro.obs import export as obs_export
from repro.obs import trace as obs_trace
from repro.obs.metrics import Registry, counter_property


# -------------------------------------------------------------------- #
# tracer
# -------------------------------------------------------------------- #
def test_tick_clock_orders_and_resets_seq():
    tr = obs_trace.Tracer()
    tr.set_tick(3)
    a = tr.instant("a")
    b = tr.instant("b")
    assert (a.tick0, a.seq0) == (3, 0)
    assert (b.tick0, b.seq0) == (3, 1)
    tr.set_tick(4)
    c = tr.instant("c")
    assert (c.tick0, c.seq0) == (4, 0)
    # sids are a plain counter: deterministic across replays
    assert [e.sid for e in (a, b, c)] == [0, 1, 2]


def test_span_context_records_args_and_duration():
    tr = obs_trace.Tracer()
    with tr.span("work", cat="decode", rank=2) as sp:
        sp.args["live"] = 5
    (e,) = list(tr.spans(cat="decode"))
    assert e.name == "work" and e.rank == 2 and e.args["live"] == 5
    assert e.kind == "span" and e.dur_us >= 0.0


def test_async_rma_span_bumps_byte_and_op_counters():
    tr = obs_trace.Tracer()
    for nbytes in (1024, 2048):
        sp = tr.begin_async("put_nb", cat="rma", bytes=nbytes)
        tr.end_async(sp)
    assert tr.registry.counter("rma_put_nb_bytes").get() == 3072
    assert tr.registry.counter("rma_put_nb_ops").get() == 2
    # non-rma async spans (e.g. the kv_handoff transfer) don't count
    sp = tr.begin_async("kv_handoff", cat="transfer", pages=3)
    tr.end_async(sp)
    assert "rma_kv_handoff_bytes" not in tr.registry


def test_ring_capacity_bounds_memory():
    tr = obs_trace.Tracer(capacity=8)
    for i in range(20):
        tr.instant(f"e{i}")
    names = [e.name for e in tr.events]
    assert names == [f"e{i}" for i in range(12, 20)]


def test_flight_window_filters_on_end_tick():
    tr = obs_trace.Tracer()
    for t in range(10):
        tr.set_tick(t)
        tr.instant(f"t{t}")
    got = {e.name for e in tr.flight(last_ticks=3)}
    assert got == {"t7", "t8", "t9"}


def test_request_stats_derives_ttft_latency_tpot():
    tr = obs_trace.Tracer()
    tr.set_tick(0)
    tr.instant("req_submit", cat="req", rid=7)
    tr.set_tick(2)
    tr.instant("req_first_token", cat="req", rid=7)
    # a second first-token (re-admit after preemption) must NOT win
    tr.set_tick(3)
    tr.instant("req_first_token", cat="req", rid=7)
    tr.set_tick(5)
    tr.instant("req_retire", cat="req", rid=7, tokens=4)
    rec = tr.request_stats()[7]
    assert rec["tokens"] == 4
    assert rec["ttft_s"] >= 0.0
    assert rec["latency_s"] >= rec["ttft_s"]
    # tpot spreads the post-first-token time over tokens-1 decode steps
    assert rec["tpot_s"] == pytest.approx(
        (rec["latency_s"] - rec["ttft_s"]) / 3
    )


def test_null_tracer_is_inert_and_enable_disable_swaps():
    assert obs_trace.active() is obs_trace.active()  # stable singleton
    null = obs_trace.active()
    assert not null.enabled
    # all no-ops: nothing raises, span() yields a reusable context
    with null.span("x") as sp:
        assert sp is None
    assert null.begin_async("y", bytes=1) is None
    null.end_async(None)
    try:
        tr = obs_trace.enable(capacity=16)
        assert obs_trace.active() is tr and tr.enabled
    finally:
        prev = obs_trace.disable()
    assert prev is tr
    assert not obs_trace.active().enabled


# -------------------------------------------------------------------- #
# metrics
# -------------------------------------------------------------------- #
def test_registry_kind_mismatch_raises():
    reg = Registry()
    reg.counter("n")
    with pytest.raises(TypeError, match="is a counter, not a gauge"):
        reg.gauge("n")


def test_reset_zeroes_counters_but_never_gauges():
    reg = Registry()
    reg.counter("c").inc(5)
    reg.gauge("g").set(11)
    reg.histogram("h").observe(3.0)
    reg.reset()
    assert reg.counter("c").get() == 0
    assert reg.gauge("g").get() == 11  # current state, not history
    assert reg.histogram("h").count == 0


def test_counter_rejects_negative_increment():
    with pytest.raises(ValueError, match="negative inc"):
        Registry().counter("c").inc(-1)


def test_histogram_quantiles_exact_below_cap():
    h = Registry().histogram("lat")  # default cap 4096 >> 100 samples
    for v in range(100):
        h.observe(float(v))
    assert h.count == 100 and h.total == pytest.approx(sum(range(100)))
    # linear interpolation at rank q*(n-1): p50 of 0..99 is 49.5, p99
    # is 98.01 — not the max (the old truncation rule returned 99.0)
    assert h.p50 == pytest.approx(49.5)
    assert h.p99 == pytest.approx(98.01)
    assert h.mean == pytest.approx(49.5)


def test_histogram_tiny_samples_are_defined():
    """The n=0 / n=1 / n=2 edges are explicit, not accidents of index
    truncation: empty -> 0.0, singleton -> the sample for EVERY q, two
    samples -> interpolation (p99 of [a, b] is no longer b outright)."""
    h = Registry().histogram("lat")
    assert h.p50 == 0.0 and h.p99 == 0.0  # n=0: no data
    h.observe(7.0)
    assert h.p50 == 7.0 and h.p99 == 7.0 and h.quantile(0.0) == 7.0
    h.observe(17.0)  # n=2: rank q*(n-1) interpolates
    assert h.p50 == pytest.approx(12.0)
    assert h.p99 == pytest.approx(7.0 + 0.99 * 10.0)
    assert h.quantile(0.0) == 7.0 and h.quantile(1.0) == 17.0
    # out-of-range q clamps instead of indexing out of bounds
    assert h.quantile(-0.5) == 7.0 and h.quantile(1.5) == 17.0


def test_histogram_decimation_is_bounded_and_deterministic():
    h = Registry().histogram("lat", cap=16)
    for v in range(100):
        h.observe(float(v))
    assert h.count == 100  # exact even after decimation
    assert len(h._samples) <= 16
    assert h.p99 >= h.p50
    # deterministic: an identical stream yields identical samples —
    # this is why decimation, not reservoir sampling
    h2 = Registry().histogram("lat", cap=16)
    for v in range(100):
        h2.observe(float(v))
    assert h._samples == h2._samples


def test_snapshot_flattens_histograms():
    reg = Registry()
    reg.counter("c").inc(2)
    reg.histogram("h").observe(4.0)
    snap = reg.snapshot()
    assert snap["c"] == 2
    assert snap["h_count"] == 1 and snap["h_mean"] == 4.0
    assert "h_p50" in snap and "h_p99" in snap


def test_counter_property_proxies_plain_increments():
    class Thing:
        hits = counter_property("thing_hits")

        def __init__(self):
            self.metrics = Registry()

    t = Thing()
    t.hits += 1
    t.hits += 2
    assert t.hits == 3
    assert t.metrics.counter("thing_hits").get() == 3
    t.metrics.reset()
    assert t.hits == 0


# -------------------------------------------------------------------- #
# export + validation
# -------------------------------------------------------------------- #
def _traced_tick():
    """One synthetic tick shaped like the disagg loop: nested scoped
    spans, a split-phase RMA closed inside, and a lifecycle instant."""
    tr = obs_trace.Tracer()
    tr.set_tick(1)
    with tr.span("tick", cat="tick"):
        with tr.span("decode", cat="decode", rank=0):
            h = tr.begin_async("put_nb", cat="rma", bytes=512, rank=0)
            tr.instant("req_retire", cat="req", rid=0, rank=0, tokens=2)
            tr.end_async(h)
    return tr


def test_chrome_trace_exports_and_validates():
    tr = _traced_tick()
    trace = obs_export.chrome_trace(tr, labels=["test"])
    assert obs_export.validate(trace, tr.registry) == []
    evs = trace["traceEvents"]
    phases = {}
    for ev in evs:
        phases.setdefault(ev["ph"], []).append(ev)
    assert {e["name"] for e in phases["X"]} == {"tick", "decode"}
    assert len(phases["b"]) == len(phases["e"]) == 1
    assert phases["b"][0]["args"]["bytes"] == 512
    names = {
        ev["args"]["name"] for ev in phases["M"]
        if ev["name"] == "thread_name"
    }
    assert names == {"gas", "rank0"}  # rank rows get readable labels


def test_validate_flags_never_synced_handle():
    tr = obs_trace.Tracer()
    sp = tr.begin_async("get_nb", cat="rma", bytes=64)
    tr.end_async(sp)
    # a second initiation that never syncs: the leak validate must catch
    leak = tr.begin_async("get_nb", cat="rma", bytes=64)
    tr.events.append(leak)  # exported open, but no end stamp recorded
    trace = obs_export.chrome_trace(tr)
    # fake the leak: strip its end event so only the begin remains
    trace["traceEvents"] = [
        ev for ev in trace["traceEvents"]
        if not (ev.get("ph") == "e" and ev.get("id") == leak.sid)
    ]
    problems = obs_export.validate(trace)
    assert any("never ended" in p for p in problems)


def test_validate_flags_byte_mismatch_with_counters():
    tr = _traced_tick()
    trace = obs_export.chrome_trace(tr)
    # simulate a lost span: the counters saw bytes the trace didn't
    tr.registry.counter("rma_put_nb_bytes").inc(1)
    problems = obs_export.validate(trace, tr.registry)
    assert any("bit-equal" in p for p in problems)


def test_validate_flags_overlapping_scoped_spans():
    tr = obs_trace.Tracer()
    tr.set_tick(0)
    a = tr.begin("a", cat="x")
    b = tr.begin("b", cat="x")
    tr.end(a)  # interleaved, not nested
    tr.end(b)
    problems = obs_export.validate(obs_export.chrome_trace(tr))
    assert any("overlaps" in p for p in problems)


def test_flight_dump_and_summary_render():
    tr = _traced_tick()
    dump = obs_export.flight_dump(
        tr, 4, reason="rank 3 (decode) died", seed=42, rank=3
    )
    assert dump["seed"] == 42 and dump["events"]
    assert dump["metrics"]["rma_put_nb_bytes"] == 512
    md = obs_export.render_flight_summary(dump)
    assert "rank 3 (decode) died" in md
    assert "--seed 42" in md  # the replay line
    assert "| tick |" in md and "put_nb" in md


# -------------------------------------------------------------------- #
# cost model feedback
# -------------------------------------------------------------------- #
def _synthetic_transfers(alpha, beta, sizes):
    return [
        {"bytes": n, "dur_us": alpha + beta * (n / 1024.0)}
        for n in sizes
    ]


def test_fit_from_trace_recovers_alpha_beta():
    spans = _synthetic_transfers(30.0, 0.8, [1024, 4096, 65536, 1 << 20])
    fit = EngineCost.fit_from_trace(spans, gamma_us_per_kib=0.0)
    assert fit.alpha_us == pytest.approx(30.0, rel=1e-6)
    assert fit.beta_us_per_kib == pytest.approx(0.8, rel=1e-6)
    assert fit.model_error(spans) == pytest.approx(0.0, abs=1e-9)
    # the stock constants are (deliberately) wrong for this data
    assert DEFAULT_COSTS["xla"].model_error(spans) > fit.model_error(spans)


def test_fit_from_trace_accepts_real_span_objects():
    tr = obs_trace.Tracer()
    for n in (1024, 8192):
        with tr.span(f"put_{n}", cat="transfer", bytes=n):
            time.sleep(0.001)  # a real (nonzero) wall duration
    fit = EngineCost.fit_from_trace(tr.spans(cat="transfer"))
    assert fit.alpha_us >= 0.0 and fit.beta_us_per_kib >= 0.0


def test_fit_from_trace_needs_two_distinct_sizes():
    with pytest.raises(ValueError, match=">= 2 measured"):
        EngineCost.fit_from_trace(_synthetic_transfers(1.0, 1.0, [4096]))
    same = _synthetic_transfers(1.0, 1.0, [4096, 4096, 4096])
    with pytest.raises(ValueError, match="two distinct sizes"):
        EngineCost.fit_from_trace(same)


def test_fit_gamma_from_trace_recovers_epilogue_slope():
    # epilogue walls: 12us dispatch overhead + 0.3us/KiB install slope;
    # the fit keeps only the slope (dispatch is not a per-KiB cost)
    epi = _synthetic_transfers(12.0, 0.3, [1 << 16, 1 << 18, 1 << 20])
    assert EngineCost.fit_gamma_from_trace(epi) == pytest.approx(
        0.3, rel=1e-6)
    # a flat (or noisy-negative) epilogue clamps to 0, never negative
    flat = _synthetic_transfers(5.0, 0.0, [1 << 16, 1 << 20])
    assert EngineCost.fit_gamma_from_trace(flat) == 0.0


def test_fit_with_epilogue_decomposes_beta_keeping_hop_us():
    """γ comes out of the measured end-to-end slope (the epilogue
    overlaps the wire — it was already inside every transfer wall), so
    α + β + γ pricing and the refit error are IDENTICAL to the
    α/β-only fit on the same spans."""
    spans = _synthetic_transfers(30.0, 0.8, [1 << 16, 1 << 18, 1 << 20])
    epi = _synthetic_transfers(10.0, 0.25, [1 << 16, 1 << 20])
    plain = EngineCost.fit_from_trace(spans, gamma_us_per_kib=0.0)
    fit = EngineCost.fit_from_trace(spans, epilogue_spans=epi)
    assert fit.gamma_us_per_kib == pytest.approx(0.25, rel=1e-6)
    assert fit.beta_us_per_kib == pytest.approx(0.8 - 0.25, rel=1e-6)
    for n in (1 << 16, 1 << 19, 1 << 21):
        assert fit.hop_us(n) == pytest.approx(plain.hop_us(n))
    assert fit.model_error(spans) == pytest.approx(
        plain.model_error(spans), abs=1e-9)
    # a measured epilogue steeper than the end-to-end slope caps at β:
    # γ can't exceed the total per-KiB cost it is a component of
    steep = _synthetic_transfers(0.0, 5.0, [1 << 16, 1 << 20])
    capped = EngineCost.fit_from_trace(spans, epilogue_spans=steep)
    assert capped.gamma_us_per_kib == pytest.approx(0.8, rel=1e-6)
    assert capped.beta_us_per_kib == pytest.approx(0.0, abs=1e-9)


def test_try_fit_from_trace_reports_instead_of_raising():
    from repro.core.sched import try_fit_from_trace

    spans = _synthetic_transfers(30.0, 0.8, [1 << 16, 1 << 20])
    fit, note = try_fit_from_trace(spans)
    assert fit is not None and note == "fit: ok"
    default = DEFAULT_COSTS["xla"]
    got, note = try_fit_from_trace([], default=default)
    assert got is default
    assert note.startswith("fit: insufficient-data")
    _, note = try_fit_from_trace(
        _synthetic_transfers(1.0, 1.0, [4096, 4096]))
    assert "two distinct sizes" in note


# -------------------------------------------------------------------- #
# request_stats lifecycle edges
# -------------------------------------------------------------------- #
def test_request_stats_preempted_resumed_request():
    tr = obs_trace.Tracer()
    tr.instant("req_submit", cat="req", rid=1)
    tr.instant("req_first_token", cat="req", rid=1)
    tr.instant("req_preempt", cat="req", rid=1, mode="swap")
    tr.instant("req_resume", cat="req", rid=1, mode="swap")
    tr.instant("req_preempt", cat="req", rid=1, mode="recompute")
    tr.instant("req_resume", cat="req", rid=1, mode="recompute")
    tr.instant("req_retire", cat="req", rid=1, tokens=6)
    rec = tr.request_stats()[1]
    assert rec["state"] == "retired"
    assert rec["preempts"] == 2 and rec["resumes"] == 2
    assert rec["preempt_modes"] == ["swap", "recompute"]
    # timing derivation unchanged by preemption
    assert rec["latency_s"] >= rec["ttft_s"] >= 0.0
    assert rec["tokens"] == 6 and "tpot_s" in rec


def test_request_stats_recompute_replayed_keeps_first_token():
    """A recompute replay re-admits the row (second req_first_token
    would be wrong — first wins) and TTFT must not move."""
    tr = obs_trace.Tracer()
    tr.instant("req_submit", cat="req", rid=2)
    tr.instant("req_first_token", cat="req", rid=2)
    t_first = tr.request_stats()[2]["t_first_us"]
    tr.instant("req_preempt", cat="req", rid=2, mode="recompute")
    time.sleep(0.001)
    tr.instant("req_first_token", cat="req", rid=2)  # replay re-bind
    tr.instant("req_retire", cat="req", rid=2, tokens=3)
    rec = tr.request_stats()[2]
    assert rec["t_first_us"] == t_first
    assert rec["preempt_modes"] == ["recompute"]


def test_request_stats_in_flight_request():
    tr = obs_trace.Tracer()
    tr.instant("req_submit", cat="req", rid=3)
    rec = tr.request_stats()[3]
    assert rec["state"] == "in-flight"
    assert "latency_s" not in rec and "tpot_s" not in rec
    tr.instant("req_first_token", cat="req", rid=3)
    rec = tr.request_stats()[3]
    assert rec["state"] == "in-flight"
    assert rec["ttft_s"] >= 0.0  # TTFT derives once the token exists
    assert "latency_s" not in rec


# -------------------------------------------------------------------- #
# critical-path attribution
# -------------------------------------------------------------------- #
def _synthetic_lifecycle(tr, rid, points):
    """Emit lifecycle events with hand-set wall stamps (us offsets)."""
    for name, t0, t1, args in points:
        if t1 is None:
            sp = tr.instant(name, cat="req", rid=rid, **args)
            sp.t0_us = sp.t1_us = float(t0)
        else:
            sp = tr.begin(name, cat="req", rid=rid, **args)
            tr.end(sp)
            sp.t0_us, sp.t1_us = float(t0), float(t1)


def test_attribute_folds_segments_and_why_slow_renders():
    from repro.obs import attrib

    tr = obs_trace.Tracer()
    # rid 0: submit@0, prefill 100..300, admit@500, preempt(swap)@800,
    # resume@1400, retire@2000 -> queue=100, prefill=200, handoff=200,
    # swap=600, decode=(2000-500)-600=900
    _synthetic_lifecycle(tr, 0, [
        ("req_submit", 0, None, {}),
        ("prefill", 100, 300, {}),
        ("req_admit", 500, None, {}),
        ("req_first_token", 500, None, {}),
        ("req_preempt", 800, None, {"mode": "swap"}),
        ("req_resume", 1400, None, {"mode": "swap"}),
        ("req_retire", 2000, None, {"tokens": 8}),
    ])
    # rid 1: resident 700..1300 — convoys rid 0's swap window
    _synthetic_lifecycle(tr, 1, [
        ("req_submit", 600, None, {}),
        ("req_admit", 700, None, {}),
        ("req_retire", 1300, None, {"tokens": 4}),
    ])
    bd = attrib.attribute(tr)[0]
    assert bd.state == "retired" and bd.total_us == 2000.0
    assert bd.segments["queue"] == pytest.approx(100.0)
    assert bd.segments["prefill"] == pytest.approx(200.0)
    assert bd.segments["handoff_wire"] == pytest.approx(200.0)
    assert bd.segments["swap"] == pytest.approx(600.0)
    assert bd.segments["decode"] == pytest.approx(900.0)
    assert bd.n_preempts == 1
    # segments tile the lifetime exactly
    assert sum(bd.segments.values()) == pytest.approx(bd.total_us)
    assert bd.dominant() == "decode"
    report = attrib.why_slow(tr, 0)
    assert "dominant: decode" in report
    assert "rid 1" in report  # the co-resident convoy
    assert "no lifecycle events" in attrib.why_slow(tr, 99)


def test_attribute_splits_handoff_by_measured_beta_gamma():
    from repro.obs import attrib

    tr = obs_trace.Tracer()
    _synthetic_lifecycle(tr, 0, [
        ("req_submit", 0, None, {}),
        ("prefill", 0, 100, {}),
        ("req_admit", 500, None, {}),  # 400us handoff window
        ("req_retire", 600, None, {"tokens": 2}),
    ])
    cost = EngineCost(alpha_us=10.0, beta_us_per_kib=0.6,
                      gamma_us_per_kib=0.2)
    bd = attrib.attribute(tr, cost=cost)[0]
    assert bd.segments["handoff_wire"] == pytest.approx(300.0)
    assert bd.segments["handoff_epilogue"] == pytest.approx(100.0)
    # without a cost model the whole window is attributed to the wire
    bd0 = attrib.attribute(tr)[0]
    assert bd0.segments["handoff_wire"] == pytest.approx(400.0)
    assert bd0.segments["handoff_epilogue"] == 0.0


def test_attribute_recompute_replay_counts_re_prefill():
    from repro.obs import attrib

    tr = obs_trace.Tracer()
    # recompute eviction 300..700; the replay's re-prefill span lands
    # INSIDE that window and must not be double-counted
    _synthetic_lifecycle(tr, 5, [
        ("req_submit", 0, None, {}),
        ("prefill", 0, 100, {}),
        ("req_admit", 100, None, {}),
        ("req_preempt", 300, None, {"mode": "recompute"}),
        ("prefill", 500, 650, {}),  # replay re-prefill
        ("req_resume", 700, None, {"mode": "recompute"}),
        ("req_retire", 1000, None, {"tokens": 5}),
    ])
    bd = attrib.attribute(tr)[5]
    # replay = eviction window (400) + re-prefill (150)
    assert bd.segments["replay"] == pytest.approx(550.0)
    assert bd.segments["swap"] == 0.0
    # decode = resident (900) - evicted (400); the re-prefill is inside
    # the evicted window, subtracted once
    assert bd.segments["decode"] == pytest.approx(500.0)
    assert bd.dominant() == "replay" or bd.segments["decode"] >= 500.0


# -------------------------------------------------------------------- #
# SLO health monitor
# -------------------------------------------------------------------- #
class _FakeSLO:
    def __init__(self, priority=0, ttft=float("inf"), tpot=float("inf")):
        self.priority = priority
        self.ttft_deadline_s = ttft
        self.tpot_deadline_s = tpot


def test_health_at_risk_fires_before_violation():
    """Deterministic pressure: with risk_frac=0.8 and an injected
    clock, the ``slo_at_risk`` instant lands on a strictly earlier tick
    than ``slo_violated``."""
    from repro.obs.health import HealthMonitor

    tr = obs_trace.enable(capacity=1024)
    try:
        mon = HealthMonitor(risk_frac=0.8)
        mon.track("r1", _FakeSLO(priority=2, ttft=1.0), now=0.0)
        ticks = {}
        for i, now in enumerate([0.5, 0.85, 1.2]):
            tr.set_tick(i)
            s = mon.tick(i, now)
            ticks[i] = s
        at_risk = [e for e in tr.spans(name="slo_at_risk")]
        violated = [e for e in tr.spans(name="slo_violated")]
        assert len(at_risk) == 1 and len(violated) == 1
        assert at_risk[0].tick0 < violated[0].tick0
        assert at_risk[0].args["deadline"] == "ttft"
        assert ticks[0]["at_risk"] == []          # 0.5/1.0 < 0.8
        assert ticks[1]["at_risk"] == ["r1"]      # early warning
        assert ticks[2]["violated"] == ["r1"]     # deadline passed
        assert mon.registry.counter("slo_violations").get() == 1
        assert mon.backpressure_floor() == 2
        assert "at_risk=1" in mon.render()
    finally:
        obs_trace.disable()


def test_health_tpot_risk_uses_ewma_and_stall():
    from repro.obs.health import HealthMonitor

    mon = HealthMonitor(risk_frac=0.8, ewma=0.5)
    mon.track("r", _FakeSLO(tpot=0.1), now=0.0)
    mon.first_token("r", now=0.0)
    # 2 tokens per tick, 0.05s/token: comfortably inside the deadline
    mon.tick(1, 0.1, progress={"r": 3})
    assert mon.last_summary["at_risk"] == []
    ewma1 = mon.last_summary["tpot_ewma_s"]["r"]
    assert ewma1 == pytest.approx(0.05)
    # then a long stall with no new tokens: projection crosses 0.8x
    mon.tick(2, 0.19, progress={"r": 3})
    assert mon.last_summary["at_risk"] == ["r"]
    # retired requests drop out of tracking entirely
    mon.tick(3, 0.2, retired=["r"])
    assert mon.last_summary["tracked"] == 0
    assert mon.backpressure_floor() is None


def test_health_inert_without_finite_deadlines():
    from repro.obs.health import HealthMonitor

    mon = HealthMonitor()
    mon.track("r", _FakeSLO(), now=0.0)  # default-inf deadlines
    mon.tick(1, 1e9)
    assert mon.last_summary["at_risk"] == []
    assert mon.backpressure_floor() is None


def test_health_backpressure_false_never_raises_floor():
    from repro.obs.health import HealthMonitor

    mon = HealthMonitor(backpressure=False)
    mon.track("r", _FakeSLO(priority=3, ttft=0.1), now=0.0)
    mon.tick(1, 5.0)  # violated outright
    assert mon.last_summary["violated"] == ["r"]
    assert mon.backpressure_floor() is None  # observe-only arm


def test_scheduler_defers_below_floor_admissions():
    from repro.obs.health import HealthMonitor
    from repro.serving.scheduler import SLO, AdmissionScheduler

    sch = AdmissionScheduler(page_bytes=4096)
    mon = HealthMonitor()
    sch.attach_health(mon)
    sch.submit(0, SLO(priority=2, ttft_deadline_s=1.0), now=0.0)
    sch.submit(1, SLO(priority=0), now=0.0)
    sch.submit(2, SLO(priority=2), now=0.0)
    mon.track(0, SLO(priority=2, ttft_deadline_s=1.0), now=0.0)
    # healthy: everything is admissible, priority-major order
    mon.tick(1, 0.1)
    assert sch.admission_order() == [0, 2, 1]
    assert sch.deferrals == 0
    # rid 0 at risk -> floor=2: the p0 request is deferred this tick
    mon.tick(2, 0.95)
    assert sch.admission_order() == [0, 2]
    assert sch.deferrals == 1
    assert sch.stats()["sched_deferrals"] == 1
    # at-risk set drains -> floor clears, nothing starves
    mon.retire(0)
    mon.tick(3, 1.0)
    assert 1 in sch.admission_order()

"""Unit tests for the observability stack: tracer, typed metrics,
Chrome-trace export + validation, flight recorder, and the measured
cost-model refit (``EngineCost.fit_from_trace``).

These are pure host-side tests — no mesh, no jit — exercising exactly
the invariants the serving instrumentation relies on: deterministic
tick-clock ordering, byte-parity between RMA spans and counters,
counter-only reset, and the never-synced-handle detection that turns a
leaked split-phase op into a validation failure.
"""
import time

import pytest

from repro.core.sched import DEFAULT_COSTS, EngineCost
from repro.obs import export as obs_export
from repro.obs import trace as obs_trace
from repro.obs.metrics import Registry, counter_property


# -------------------------------------------------------------------- #
# tracer
# -------------------------------------------------------------------- #
def test_tick_clock_orders_and_resets_seq():
    tr = obs_trace.Tracer()
    tr.set_tick(3)
    a = tr.instant("a")
    b = tr.instant("b")
    assert (a.tick0, a.seq0) == (3, 0)
    assert (b.tick0, b.seq0) == (3, 1)
    tr.set_tick(4)
    c = tr.instant("c")
    assert (c.tick0, c.seq0) == (4, 0)
    # sids are a plain counter: deterministic across replays
    assert [e.sid for e in (a, b, c)] == [0, 1, 2]


def test_span_context_records_args_and_duration():
    tr = obs_trace.Tracer()
    with tr.span("work", cat="decode", rank=2) as sp:
        sp.args["live"] = 5
    (e,) = list(tr.spans(cat="decode"))
    assert e.name == "work" and e.rank == 2 and e.args["live"] == 5
    assert e.kind == "span" and e.dur_us >= 0.0


def test_async_rma_span_bumps_byte_and_op_counters():
    tr = obs_trace.Tracer()
    for nbytes in (1024, 2048):
        sp = tr.begin_async("put_nb", cat="rma", bytes=nbytes)
        tr.end_async(sp)
    assert tr.registry.counter("rma_put_nb_bytes").get() == 3072
    assert tr.registry.counter("rma_put_nb_ops").get() == 2
    # non-rma async spans (e.g. the kv_handoff transfer) don't count
    sp = tr.begin_async("kv_handoff", cat="transfer", pages=3)
    tr.end_async(sp)
    assert "rma_kv_handoff_bytes" not in tr.registry


def test_ring_capacity_bounds_memory():
    tr = obs_trace.Tracer(capacity=8)
    for i in range(20):
        tr.instant(f"e{i}")
    names = [e.name for e in tr.events]
    assert names == [f"e{i}" for i in range(12, 20)]


def test_flight_window_filters_on_end_tick():
    tr = obs_trace.Tracer()
    for t in range(10):
        tr.set_tick(t)
        tr.instant(f"t{t}")
    got = {e.name for e in tr.flight(last_ticks=3)}
    assert got == {"t7", "t8", "t9"}


def test_request_stats_derives_ttft_latency_tpot():
    tr = obs_trace.Tracer()
    tr.set_tick(0)
    tr.instant("req_submit", cat="req", rid=7)
    tr.set_tick(2)
    tr.instant("req_first_token", cat="req", rid=7)
    # a second first-token (re-admit after preemption) must NOT win
    tr.set_tick(3)
    tr.instant("req_first_token", cat="req", rid=7)
    tr.set_tick(5)
    tr.instant("req_retire", cat="req", rid=7, tokens=4)
    rec = tr.request_stats()[7]
    assert rec["tokens"] == 4
    assert rec["ttft_s"] >= 0.0
    assert rec["latency_s"] >= rec["ttft_s"]
    # tpot spreads the post-first-token time over tokens-1 decode steps
    assert rec["tpot_s"] == pytest.approx(
        (rec["latency_s"] - rec["ttft_s"]) / 3
    )


def test_null_tracer_is_inert_and_enable_disable_swaps():
    assert obs_trace.active() is obs_trace.active()  # stable singleton
    null = obs_trace.active()
    assert not null.enabled
    # all no-ops: nothing raises, span() yields a reusable context
    with null.span("x") as sp:
        assert sp is None
    assert null.begin_async("y", bytes=1) is None
    null.end_async(None)
    try:
        tr = obs_trace.enable(capacity=16)
        assert obs_trace.active() is tr and tr.enabled
    finally:
        prev = obs_trace.disable()
    assert prev is tr
    assert not obs_trace.active().enabled


# -------------------------------------------------------------------- #
# metrics
# -------------------------------------------------------------------- #
def test_registry_kind_mismatch_raises():
    reg = Registry()
    reg.counter("n")
    with pytest.raises(TypeError, match="is a counter, not a gauge"):
        reg.gauge("n")


def test_reset_zeroes_counters_but_never_gauges():
    reg = Registry()
    reg.counter("c").inc(5)
    reg.gauge("g").set(11)
    reg.histogram("h").observe(3.0)
    reg.reset()
    assert reg.counter("c").get() == 0
    assert reg.gauge("g").get() == 11  # current state, not history
    assert reg.histogram("h").count == 0


def test_counter_rejects_negative_increment():
    with pytest.raises(ValueError, match="negative inc"):
        Registry().counter("c").inc(-1)


def test_histogram_quantiles_exact_below_cap():
    h = Registry().histogram("lat")  # default cap 4096 >> 100 samples
    for v in range(100):
        h.observe(float(v))
    assert h.count == 100 and h.total == pytest.approx(sum(range(100)))
    assert h.p50 == 50.0 and h.p99 == 99.0
    assert h.mean == pytest.approx(49.5)


def test_histogram_decimation_is_bounded_and_deterministic():
    h = Registry().histogram("lat", cap=16)
    for v in range(100):
        h.observe(float(v))
    assert h.count == 100  # exact even after decimation
    assert len(h._samples) <= 16
    assert h.p99 >= h.p50
    # deterministic: an identical stream yields identical samples —
    # this is why decimation, not reservoir sampling
    h2 = Registry().histogram("lat", cap=16)
    for v in range(100):
        h2.observe(float(v))
    assert h._samples == h2._samples


def test_snapshot_flattens_histograms():
    reg = Registry()
    reg.counter("c").inc(2)
    reg.histogram("h").observe(4.0)
    snap = reg.snapshot()
    assert snap["c"] == 2
    assert snap["h_count"] == 1 and snap["h_mean"] == 4.0
    assert "h_p50" in snap and "h_p99" in snap


def test_counter_property_proxies_plain_increments():
    class Thing:
        hits = counter_property("thing_hits")

        def __init__(self):
            self.metrics = Registry()

    t = Thing()
    t.hits += 1
    t.hits += 2
    assert t.hits == 3
    assert t.metrics.counter("thing_hits").get() == 3
    t.metrics.reset()
    assert t.hits == 0


# -------------------------------------------------------------------- #
# export + validation
# -------------------------------------------------------------------- #
def _traced_tick():
    """One synthetic tick shaped like the disagg loop: nested scoped
    spans, a split-phase RMA closed inside, and a lifecycle instant."""
    tr = obs_trace.Tracer()
    tr.set_tick(1)
    with tr.span("tick", cat="tick"):
        with tr.span("decode", cat="decode", rank=0):
            h = tr.begin_async("put_nb", cat="rma", bytes=512, rank=0)
            tr.instant("req_retire", cat="req", rid=0, rank=0, tokens=2)
            tr.end_async(h)
    return tr


def test_chrome_trace_exports_and_validates():
    tr = _traced_tick()
    trace = obs_export.chrome_trace(tr, labels=["test"])
    assert obs_export.validate(trace, tr.registry) == []
    evs = trace["traceEvents"]
    phases = {}
    for ev in evs:
        phases.setdefault(ev["ph"], []).append(ev)
    assert {e["name"] for e in phases["X"]} == {"tick", "decode"}
    assert len(phases["b"]) == len(phases["e"]) == 1
    assert phases["b"][0]["args"]["bytes"] == 512
    names = {
        ev["args"]["name"] for ev in phases["M"]
        if ev["name"] == "thread_name"
    }
    assert names == {"gas", "rank0"}  # rank rows get readable labels


def test_validate_flags_never_synced_handle():
    tr = obs_trace.Tracer()
    sp = tr.begin_async("get_nb", cat="rma", bytes=64)
    tr.end_async(sp)
    # a second initiation that never syncs: the leak validate must catch
    leak = tr.begin_async("get_nb", cat="rma", bytes=64)
    tr.events.append(leak)  # exported open, but no end stamp recorded
    trace = obs_export.chrome_trace(tr)
    # fake the leak: strip its end event so only the begin remains
    trace["traceEvents"] = [
        ev for ev in trace["traceEvents"]
        if not (ev.get("ph") == "e" and ev.get("id") == leak.sid)
    ]
    problems = obs_export.validate(trace)
    assert any("never ended" in p for p in problems)


def test_validate_flags_byte_mismatch_with_counters():
    tr = _traced_tick()
    trace = obs_export.chrome_trace(tr)
    # simulate a lost span: the counters saw bytes the trace didn't
    tr.registry.counter("rma_put_nb_bytes").inc(1)
    problems = obs_export.validate(trace, tr.registry)
    assert any("bit-equal" in p for p in problems)


def test_validate_flags_overlapping_scoped_spans():
    tr = obs_trace.Tracer()
    tr.set_tick(0)
    a = tr.begin("a", cat="x")
    b = tr.begin("b", cat="x")
    tr.end(a)  # interleaved, not nested
    tr.end(b)
    problems = obs_export.validate(obs_export.chrome_trace(tr))
    assert any("overlaps" in p for p in problems)


def test_flight_dump_and_summary_render():
    tr = _traced_tick()
    dump = obs_export.flight_dump(
        tr, 4, reason="rank 3 (decode) died", seed=42, rank=3
    )
    assert dump["seed"] == 42 and dump["events"]
    assert dump["metrics"]["rma_put_nb_bytes"] == 512
    md = obs_export.render_flight_summary(dump)
    assert "rank 3 (decode) died" in md
    assert "--seed 42" in md  # the replay line
    assert "| tick |" in md and "put_nb" in md


# -------------------------------------------------------------------- #
# cost model feedback
# -------------------------------------------------------------------- #
def _synthetic_transfers(alpha, beta, sizes):
    return [
        {"bytes": n, "dur_us": alpha + beta * (n / 1024.0)}
        for n in sizes
    ]


def test_fit_from_trace_recovers_alpha_beta():
    spans = _synthetic_transfers(30.0, 0.8, [1024, 4096, 65536, 1 << 20])
    fit = EngineCost.fit_from_trace(spans, gamma_us_per_kib=0.0)
    assert fit.alpha_us == pytest.approx(30.0, rel=1e-6)
    assert fit.beta_us_per_kib == pytest.approx(0.8, rel=1e-6)
    assert fit.model_error(spans) == pytest.approx(0.0, abs=1e-9)
    # the stock constants are (deliberately) wrong for this data
    assert DEFAULT_COSTS["xla"].model_error(spans) > fit.model_error(spans)


def test_fit_from_trace_accepts_real_span_objects():
    tr = obs_trace.Tracer()
    for n in (1024, 8192):
        with tr.span(f"put_{n}", cat="transfer", bytes=n):
            time.sleep(0.001)  # a real (nonzero) wall duration
    fit = EngineCost.fit_from_trace(tr.spans(cat="transfer"))
    assert fit.alpha_us >= 0.0 and fit.beta_us_per_kib >= 0.0


def test_fit_from_trace_needs_two_distinct_sizes():
    with pytest.raises(ValueError, match=">= 2 measured"):
        EngineCost.fit_from_trace(_synthetic_transfers(1.0, 1.0, [4096]))
    same = _synthetic_transfers(1.0, 1.0, [4096, 4096, 4096])
    with pytest.raises(ValueError, match="two distinct sizes"):
        EngineCost.fit_from_trace(same)

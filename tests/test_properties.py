"""Hypothesis property tests on system invariants.

Requires the optional ``hypothesis`` test dependency (declared in
pyproject.toml's ``test`` extra); the whole module skips cleanly when it
is not installed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import am
from repro.core import collectives as coll
from repro.core import sched
from repro.kernels import ref
from repro.models.common import build_layer_program
from repro.optim import adamw, compression
from repro.parallel.sharding import sanitize
from repro.runtime.ft import elastic_plan
from repro.testing.sim import run_spmd

SET = settings(max_examples=25, deadline=None)
# lockstep-simulator tests run every rank to fixpoint; keep them lean
SET_SIM = settings(max_examples=10, deadline=None)


# --------------------------------------------------------------------------- #
# MoE routing invariants
# --------------------------------------------------------------------------- #
@SET
@given(
    t=st.integers(4, 64),
    e=st.integers(2, 16),
    k=st.integers(1, 4),
    cap=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_route_topk_invariants(t, e, k, cap, seed):
    k = min(k, e)
    logits = jnp.asarray(
        np.random.default_rng(seed).normal(size=(t, e)), jnp.float32
    )
    eidx, slot, w, keep = ref.route_topk(logits, k=k, capacity=cap)
    eidx, slot, w, keep = map(np.asarray, (eidx, slot, w, keep))
    # (1) kept slots are within capacity
    assert (slot[keep] < cap).all()
    # (2) slot uniqueness: no two kept (token,choice) share (expert, slot)
    pairs = list(zip(eidx[keep].tolist(), slot[keep].tolist()))
    assert len(pairs) == len(set(pairs))
    # (3) top-k weights are normalized over the full top-k set
    np.testing.assert_allclose(w.sum(-1), 1.0, atol=1e-5)
    # (4) expert ids are distinct per token
    for row in eidx:
        assert len(set(row.tolist())) == k


@SET
@given(
    t=st.integers(4, 32),
    e=st.integers(2, 8),
    d=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_dispatch_combine_conservation(t, e, d, seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(t, e)), jnp.float32)
    tokens = jnp.asarray(rng.normal(size=(t, d)), jnp.float32)
    k = min(2, e)
    eidx, slot, w, keep = ref.route_topk(logits, k=k, capacity=t)
    buf = ref.moe_dispatch(tokens, eidx, slot, keep, n_experts=e, capacity=t)
    out = ref.moe_combine(buf, eidx, slot, w, keep)
    np.testing.assert_allclose(np.asarray(out), np.asarray(tokens),
                               atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------------- #
# Active Message send-buffer invariants (the GAScore schedule builder)
# --------------------------------------------------------------------------- #
@SET
@given(
    cap=st.integers(1, 16),
    n_nodes=st.integers(2, 8),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_am_send_buffer_invariants(cap, n_nodes, k, seed):
    rng = np.random.default_rng(seed)
    batch = am.empty_batch(cap, payload_width=2)
    n_msgs = int(rng.integers(0, cap + 1))
    dests = rng.integers(0, n_nodes, size=n_msgs)
    for d in dests:
        batch = am.push(batch, int(d), 0, args=(1,),
                        payload=jnp.ones((2,), jnp.float32))
    packed, dropped = am.build_send_buffer(batch, n_nodes, k)
    packed_valid = np.asarray(packed.valid)
    dest_arr = np.asarray(packed.dest)
    # conservation: delivered + dropped == sent
    assert packed_valid.sum() + int(dropped) == n_msgs
    # capacity: at most k messages per destination block, in the right block
    for dnode in range(n_nodes):
        blk = packed_valid[dnode * k : (dnode + 1) * k]
        assert blk.sum() <= k
        assert (dest_arr[dnode * k : (dnode + 1) * k][blk] == dnode).all()
    # per-destination drops only happen when over capacity
    sent_per_dest = np.bincount(dests, minlength=n_nodes)
    expect_dropped = np.maximum(sent_per_dest - k, 0).sum()
    assert int(dropped) == expect_dropped


# --------------------------------------------------------------------------- #
# segmented collectives: bit-exact vs monolithic for ANY n_segments/depth
# (the scheduler's pipelining must be semantics-transparent)
# --------------------------------------------------------------------------- #
def _rank_arrays(rng, n, rows, cols, lo=-1000, hi=1000):
    return [
        jnp.asarray(rng.integers(lo, hi, size=(rows, cols)), jnp.int32)
        for _ in range(n)
    ]


@SET_SIM
@given(
    n=st.integers(2, 5),
    rows=st.integers(1, 6),
    cols=st.integers(1, 3),
    n_segments=st.integers(1, 9),
    depth=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_segmented_all_gather_bitexact(n, rows, cols, n_segments, depth, seed):
    xs = _rank_arrays(np.random.default_rng(seed), n, rows, cols)
    seg = run_spmd(
        lambda e: coll.segmented_ring_all_gather(
            e, xs[e.rank], n_segments=n_segments, depth=depth
        ),
        n,
    )
    mono = run_spmd(lambda e: coll.ring_all_gather(e, xs[e.rank]), n)
    oracle = np.concatenate([np.asarray(x) for x in xs], axis=0)
    for a, b in zip(seg, mono):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(a), oracle)


@SET_SIM
@given(
    n=st.integers(2, 5),
    m=st.integers(1, 5),
    cols=st.integers(1, 3),
    n_segments=st.integers(1, 9),
    depth=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_segmented_reduce_scatter_bitexact(n, m, cols, n_segments, depth, seed):
    xs = _rank_arrays(np.random.default_rng(seed), n, n * m, cols)
    seg = run_spmd(
        lambda e: coll.segmented_ring_reduce_scatter(
            e, xs[e.rank], n_segments=n_segments, depth=depth
        ),
        n,
    )
    mono = run_spmd(lambda e: coll.ring_reduce_scatter(e, xs[e.rank]), n)
    total = np.sum([np.asarray(x) for x in xs], axis=0)
    for r, (a, b) in enumerate(zip(seg, mono)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(
            np.asarray(a), total[r * m : (r + 1) * m]
        )


@SET_SIM
@given(
    n=st.integers(2, 4),
    m=st.integers(1, 4),
    n_segments=st.integers(1, 7),
    depth=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_segmented_all_reduce_bitexact(n, m, n_segments, depth, seed):
    xs = _rank_arrays(np.random.default_rng(seed), n, n * m, 2)
    seg = run_spmd(
        lambda e: coll.segmented_ring_all_reduce(
            e, xs[e.rank], n_segments=n_segments, depth=depth
        ),
        n,
    )
    mono = run_spmd(lambda e: coll.ring_all_reduce(e, xs[e.rank]), n)
    total = np.sum([np.asarray(x) for x in xs], axis=0)
    for a, b in zip(seg, mono):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(a), total)


@SET_SIM
@given(
    logn=st.integers(1, 3),
    width=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_recursive_doubling_matches_sum(logn, width, seed):
    n = 1 << logn
    rng = np.random.default_rng(seed)
    xs = [
        jnp.asarray(rng.integers(-1000, 1000, size=(width,)), jnp.int32)
        for _ in range(n)
    ]
    outs = run_spmd(
        lambda e: coll.recursive_doubling_all_reduce(e, xs[e.rank]), n
    )
    total = np.sum([np.asarray(x) for x in xs], axis=0)
    for o in outs:
        np.testing.assert_array_equal(np.asarray(o), total)


@SET_SIM
@given(
    n=st.integers(2, 8),
    root=st.integers(0, 7),
    width=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_tree_broadcast_delivers_root(n, root, width, seed):
    root = root % n
    rng = np.random.default_rng(seed)
    xs = [
        jnp.asarray(rng.integers(-99, 99, size=(width,)), jnp.int32)
        for _ in range(n)
    ]
    outs = run_spmd(lambda e: coll.tree_broadcast(e, xs[e.rank], root=root), n)
    for o in outs:
        np.testing.assert_array_equal(np.asarray(o), np.asarray(xs[root]))


# --------------------------------------------------------------------------- #
# segmented KV-block handoff: bit-exact for ANY segment count / block size
# (the disaggregated-serving data plane must be semantics-transparent)
# --------------------------------------------------------------------------- #
@SET_SIM
@given(
    n=st.integers(2, 5),
    block=st.integers(1, 48),
    n_segments=st.integers(1, 9),
    n_slots=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_segmented_kv_handoff_bitexact(n, block, n_segments, n_slots, seed):
    from repro.core import gasnet
    from repro.serving import kv as skv

    slot = seed % n_slots
    rng = np.random.default_rng(seed)
    # int bit patterns through the float32 carrier: any payload must
    # survive the segmented handoff bit-for-bit
    blocks = [
        jnp.asarray(
            rng.integers(-(2**31), 2**31 - 1, size=(block,), dtype=np.int64)
            .astype(np.int32)
        )
        for _ in range(n)
    ]

    def program(g):
        def run(engine):
            node = gasnet.Node(
                engine, am.HandlerTable(), am_capacity=4,
                am_payload_width=1, am_per_peer_capacity=4,
            )
            seg = jnp.zeros((1, n_slots * block), jnp.float32)
            flat = skv._to_carrier(blocks[engine.rank])
            handles, _ = skv.push_block(
                node, seg, flat, to=gasnet.Shift(1),
                base_index=slot * block, n_segments=g,
            )
            seg = skv.sync_push(node, seg, handles)
            return seg

        return run

    segmented = run_spmd(program(n_segments), n)
    mono = run_spmd(program(1), n)
    for rank, (a, b) in enumerate(zip(segmented, mono)):
        a, b = np.asarray(a), np.asarray(b)
        np.testing.assert_array_equal(a, b)
        got = a[0, slot * block : (slot + 1) * block]
        want = np.asarray(blocks[(rank - 1) % n]).view(np.float32)
        np.testing.assert_array_equal(got, want)


# --------------------------------------------------------------------------- #
# paged KV pool: the allocator never double-frees or leaks, and the page
# layout round-trips any bit pattern (NaNs included) through the carrier
# --------------------------------------------------------------------------- #
@SET
@given(
    n_pages=st.integers(1, 12),
    ops=st.lists(
        st.tuples(st.sampled_from(["alloc", "free", "fork", "cow"]),
                  st.integers(0, 2**31 - 1)),
        min_size=0, max_size=40,
    ),
)
def test_pool_allocator_never_leaks_or_double_frees(n_pages, ops):
    from repro.serving import pool

    state = pool.make_pool(n_pages)
    refs = []  # live references (page ids with multiplicity == refcount)
    for op, r in ops:
        if op == "alloc":
            k = r % (state.n_free + 1)
            state, pages = pool.alloc(state, k)
            assert len(set(pages)) == len(pages)
            assert all(state.refcnt[p] == 1 for p in pages)
            refs.extend(pages)
        elif op == "free" and refs:
            k = r % len(refs) + 1
            drop = [refs.pop(r % len(refs)) for _ in range(k)]
            state = pool.free(state, drop)
        elif op == "fork" and refs:
            page = refs[r % len(refs)]
            state = pool.fork(state, (page,))
            refs.append(page)
        elif op == "cow" and refs:
            i = r % len(refs)
            if state.refcnt[refs[i]] > 1 and state.n_free == 0:
                # COW needs a fresh page; the functional state survives
                # the failed attempt untouched
                with pytest.raises(pool.OutOfPagesError):
                    pool.writable(state, refs[i])
            else:
                state, fresh, copied = pool.writable(state, refs[i])
                # the writable page always ends privately held
                assert state.refcnt[fresh] == 1
                assert copied == (fresh != refs[i])
                refs[i] = fresh
        pool.check_pool(state)
        assert state.n_free + len(set(refs)) == n_pages
    # release every remaining reference: the pool must drain exactly
    state = pool.free(state, refs)
    pool.check_pool(state)
    assert state.n_free == n_pages
    # and the drained pool rejects another free of any page
    if refs:
        with pytest.raises(pool.DoubleFreeError):
            pool.free(state, (refs[0],))


@SET
@given(
    page_tokens=st.integers(1, 4),
    n_pages=st.integers(1, 4),
    layers=st.integers(1, 3),
    heads=st.integers(1, 3),
    dh=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_paged_layout_round_trip_bitexact(
    page_tokens, n_pages, layers, heads, dh, seed
):
    """flatten/unflatten of the paged layout is a bit-exact involution for
    ANY payload — float leaves are fed raw random bit patterns (NaNs and
    denormals included) and int/bool leaves ride the same carrier."""
    from repro.serving import pool

    W = page_tokens * n_pages
    if W == 1:
        return  # the size-1 batch dims would make the token axis ambiguous
    # keep the token axis unambiguous: no other dim may equal W
    layers, heads, dh = (d + 1 if d == W else d for d in (layers, heads, dh))
    rng = np.random.default_rng(seed)
    bits = rng.integers(-(2**31), 2**31 - 1, size=(layers, 1, W, heads, dh),
                        dtype=np.int64).astype(np.int32)
    caches = {
        "k": jnp.asarray(bits.view(np.float32)),  # raw bits incl. NaNs
        "pos": jnp.asarray(
            rng.integers(-(2**31), 2**31 - 1, size=(layers, 1, W),
                         dtype=np.int64).astype(np.int32)
        ),
        "gate": jnp.asarray(rng.integers(0, 2, size=(1, W)) > 0),
    }
    struct = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), caches
    )
    layout = pool.PagedLayout.from_struct(
        struct, cache_len=W, page_tokens=page_tokens
    )
    pages = layout.flatten(caches)
    assert pages.shape == (n_pages, layout.page_elems)
    back = layout.unflatten(pages)
    for a, b in zip(jax.tree.leaves(caches), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        # bitwise equality: NaN payloads must survive the carrier
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


@SET
@given(
    op=st.sampled_from(["all_reduce", "all_gather", "reduce_scatter",
                        "broadcast", "all_to_all"]),
    nbytes=st.integers(1, 1 << 28),
    n_nodes=st.integers(1, 64),
)
def test_planner_total_and_deterministic(op, nbytes, n_nodes):
    p = sched.plan_collective(op, nbytes=nbytes, n_nodes=n_nodes)
    q = sched.plan_collective(op, nbytes=nbytes, n_nodes=n_nodes)
    assert p == q  # planning is pure
    assert p.algorithm in ("ring", "recursive_doubling", "tree", "direct",
                           "native")
    assert 1 <= p.n_segments <= sched.MAX_SEGMENTS
    assert p.depth >= 1
    assert p.est_us >= 0.0
    if p.algorithm == "recursive_doubling":
        assert n_nodes & (n_nodes - 1) == 0


# --------------------------------------------------------------------------- #
# tiered KV memory: scheduler + pool + tier under random preemption traffic
# --------------------------------------------------------------------------- #
@settings(max_examples=15, deadline=None)
@given(
    n_pages=st.integers(3, 8),
    n_reqs=st.integers(2, 5),
    ops=st.lists(
        st.tuples(
            st.sampled_from(["advance", "preempt_swap", "preempt_rec", "tick"]),
            st.integers(0, 2**31 - 1),
        ),
        max_size=30,
    ),
    seed=st.integers(0, 2**31 - 1),
)
def test_tiered_scheduler_never_starves_leaks_or_corrupts(
    n_pages, n_reqs, ops, seed
):
    """Random admit/preempt(swap|recompute)/resume/retire traffic over the
    real store + tier + scheduler: no request starves (everything drains
    within a bounded number of ticks), the pool never leaks or
    double-frees, and every request's final KV bytes — NaN payloads
    included — are bit-exact vs a never-preempted execution."""
    import jax as _jax

    from repro.serving import pool as plib
    from repro.serving import tier as tlib
    from repro.serving.scheduler import SLO, AdmissionScheduler

    PT, NP, ROWS = 2, 4, 2
    W = PT * NP
    struct = {
        "k": _jax.ShapeDtypeStruct((1, 1, W, 2), jnp.float32),
        "pos": _jax.ShapeDtypeStruct((1, 1, W), jnp.int32),
    }
    layout = plib.PagedLayout.from_struct(struct, cache_len=W, page_tokens=PT)
    store = plib.PagedKVStore(layout, n_pages)
    tier = tlib.MemoryTier(
        1, max(n_pages, NP * n_reqs), layout.page_elems, host_backed=True
    )
    sched_ = AdmissionScheduler(page_bytes=layout.page_bytes)

    rng = np.random.default_rng(seed)
    prompt_len = {r: int(rng.integers(1, W // 2 + 1)) for r in range(n_reqs)}
    total_len = {
        r: int(rng.integers(prompt_len[r] + 1, W + 1)) for r in range(n_reqs)
    }
    for r in range(n_reqs):
        sched_.submit(r, SLO(priority=int(rng.integers(0, 3))), now=float(r))

    def row_bytes(rid, page, last_pos):
        bits = np.random.default_rng(
            (rid * 131 + page) * 977 + last_pos
        ).integers(-(2**31), 2**31 - 1, size=layout.page_elems, dtype=np.int64)
        return bits.astype(np.int32).view(np.float32)

    def prompt_row(page):
        # full prompt pages are rid-INDEPENDENT: identical prompts yield
        # identical KV bytes — the prefix-sharing contract
        bits = np.random.default_rng(777 + page).integers(
            -(2**31), 2**31 - 1, size=layout.page_elems, dtype=np.int64
        )
        return bits.astype(np.int32).view(np.float32)

    def page_row(rid, page):
        """Content of one page after the write covering its last live
        position (prompts are common prefixes: range(prompt_len))."""
        if page < prompt_len[rid] // PT:
            return prompt_row(page)
        last = min(total_len[rid], (page + 1) * PT) - 1
        return row_bytes(rid, page, max(last, prompt_len[rid] - 1))

    def final_rows(rid):  # the never-preempted oracle
        return {
            p: page_row(rid, p)
            for p in range(layout.pages_for(total_len[rid]))
        }

    oracle = {r: final_rows(r) for r in range(n_reqs)}
    written = {}  # rid -> positions written so far
    running, preempted, done = set(), {}, set()
    evicted_tables = []

    def checks():
        plib.check_pool(
            store.state,
            tables=store.tables.values(),
            evicted=evicted_tables,
        )
        tlib.check_tier(tier, resident_rids=store.tables.keys())
        distinct = {p for t in store.tables.values() for p in t if p >= 0}
        assert store.n_free + len(distinct) == n_pages  # no leak

    def write_pos(rid, pos):
        phys = store.prepare_write(rid, pos)
        store.mem[phys] = row_bytes(rid, pos // PT, pos)

    def write_prompt_pages(rid, plan):
        # fresh pages only: prefix-shared (forked) pages already hold the
        # identical prompt bytes and must never be rewritten
        for p in range(layout.pages_for(prompt_len[rid])):
            if plan.fresh[p]:
                store.mem[plan.table[p]] = (
                    prompt_row(p)
                    if p < prompt_len[rid] // PT
                    else row_bytes(rid, p, prompt_len[rid] - 1)
                )

    def admit(rid):
        plan = store.plan_admit(list(range(prompt_len[rid])), lazy=True)
        store.commit(rid, plan)
        write_prompt_pages(rid, plan)
        written[rid] = prompt_len[rid]
        running.add(rid)
        sched_.on_admitted(rid)

    def retire(rid):
        # bit-exactness vs the never-preempted oracle, NaN-safe
        table = store.page_table(rid)
        for p, want in oracle[rid].items():
            assert store.mem[table[p]].tobytes() == want.tobytes(), (
                f"rid {rid} page {p} corrupted"
            )
        store.release(rid)
        running.discard(rid)
        done.add(rid)
        sched_.on_done(rid)

    def preempt(rid, mode):
        logical = [lp for lp, pp in enumerate(store.page_table(rid)) if pp >= 0]
        if mode == "swap":
            try:
                hold = tier.plan_swap_out(rid, logical)
            except tlib.OutOfSlotsError:
                mode = "recompute"
            else:
                table = store.page_table(rid)
                tier.host_store(
                    rid, np.stack([store.mem[table[lp]] for lp in hold.logical])
                )
        pairs = store.evict_request(rid)
        evicted_tables.append([pp for _, pp in pairs])
        running.discard(rid)
        preempted[rid] = {"mode": mode, "logical": tuple(logical)}
        sched_.on_preempted(rid, mode)

    def advance(rid):
        if written[rid] >= total_len[rid]:
            retire(rid)
            return
        pos = written[rid]
        table = store.page_table(rid)
        if table[pos // PT] == plib.UNMATERIALIZED and store.n_free < 1:
            victims = sched_.pick_victims(
                sorted(running), 1,
                lambda v: sum(
                    1 for p in store.page_table(v)
                    if p >= 0 and store.state.refcnt[p] == 1
                ),
                beneficiary=rid,
            )
            for v in victims or [rid]:
                preempt(v, "swap" if v % 2 else "recompute")
            if not victims:
                return
        write_pos(rid, pos)
        written[rid] = pos + 1
        sched_.on_step(rid)

    def tick():
        for rid in sched_.admission_order():
            if len(running) >= ROWS:
                return
            if rid in preempted:
                st = preempted[rid]
                if st["mode"] == "swap":
                    if store.n_free < len(st["logical"]):
                        continue
                    phys = store.admit_resume(rid, st["logical"])
                    rows = tier.host_load(rid)
                    tier.release(rid)
                    for row, pp in zip(rows, phys):
                        store.mem[pp] = row
                else:  # recompute: re-prefill + replay, bit-identical
                    # conservative gate: replay must re-materialise every
                    # page written so far, not just the prompt pages
                    if store.n_free < layout.pages_for(written[rid]):
                        continue
                    plan = store.plan_admit(
                        list(range(prompt_len[rid])), lazy=True
                    )
                    store.commit(rid, plan)
                    write_prompt_pages(rid, plan)
                    for pos in range(prompt_len[rid], written[rid]):
                        write_pos(rid, pos)
                del preempted[rid]
                running.add(rid)
                sched_.on_admitted(rid)
            elif rid not in done and rid not in running and rid in written:
                continue
            elif rid not in done and rid not in running and rid not in written:
                if store.n_free < layout.pages_for(prompt_len[rid]):
                    continue
                admit(rid)

    for op, arg in ops:
        live = sorted(running)
        if op == "advance" and live:
            advance(live[arg % len(live)])
        elif op == "preempt_swap" and live:
            preempt(live[arg % len(live)], "swap")
        elif op == "preempt_rec" and live:
            preempt(live[arg % len(live)], "recompute")
        elif op == "tick":
            tick()
        checks()
    # no starvation: with the pool at least one request wide, everything
    # drains in bounded ticks under the resume-first admission order
    if n_pages >= layout.pages_for(max(total_len.values())):
        for _ in range(20 * n_reqs * W):
            if len(done) == n_reqs:
                break
            tick()
            for rid in sorted(running):
                if rid in running:  # an earlier advance may have evicted it
                    advance(rid)
            checks()
        assert len(done) == n_reqs, (
            f"starved: {done=} {running=} {preempted=}"
        )
        assert store.n_free == n_pages
        assert tier.n_free == tier.n_ranks * tier.slots_per_rank
@SET
@given(
    n=st.integers(8, 512),
    scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_roundtrip_bound(n, scale, seed):
    x = jnp.asarray(
        np.random.default_rng(seed).normal(size=(n,)) * scale, jnp.float32
    )
    q, s = compression.quantize_int8(x)
    err = np.abs(np.asarray(compression.dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6  # half-ULP of the int8 grid


@SET
@given(n=st.integers(8, 256), seed=st.integers(0, 2**31 - 1))
def test_error_feedback_residual(n, seed):
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(n,)), jnp.float32)
    err0 = jnp.zeros_like(x)
    q, s, err1 = compression.ef_prepare(x, err0)
    # residual equals exactly what quantization destroyed
    recon = compression.dequantize_int8(q, s)
    np.testing.assert_allclose(
        np.asarray(recon + err1), np.asarray(x), atol=1e-5
    )


# --------------------------------------------------------------------------- #
# layer program compilation
# --------------------------------------------------------------------------- #
@SET
@given(
    pattern=st.lists(
        st.sampled_from(["global", "local", "moe", "mamba", "rec"]),
        min_size=1, max_size=4,
    ),
    n_layers=st.integers(1, 64),
)
def test_layer_program_covers_exactly(pattern, n_layers):
    kinds = [pattern[i % len(pattern)] for i in range(n_layers)]
    segs = build_layer_program(kinds)
    flat = []
    for s in segs:
        flat.extend(list(s.unit) * s.count)
    assert flat == kinds  # exact cover, order preserved


# --------------------------------------------------------------------------- #
# sharding sanitizer
# --------------------------------------------------------------------------- #
def test_sanitize_drops_nondividing_axes():
    import jax
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), ("model",))  # single device: model size 1
    # size-1 axes always divide; use shape math directly on a fake mesh-like
    spec = sanitize(P("model", None), (7, 3), mesh)
    assert spec == P("model", None)  # size-1 axis divides everything


@SET
@given(
    alive=st.integers(0, 600),
    width=st.integers(1, 64),
    pods=st.integers(1, 4),
)
def test_elastic_plan_properties(alive, width, pods):
    plan = elastic_plan(alive, width, prefer_pods=pods)
    if plan is None:
        assert alive < width
        return
    p, d, m = plan
    assert m == width  # TP degree preserved
    assert p * d * m <= alive  # never over-subscribes survivors
    assert p >= 1 and d >= 1


# --------------------------------------------------------------------------- #
# schedule
# --------------------------------------------------------------------------- #
@SET
@given(
    base=st.floats(1e-5, 1e-2),
    warm=st.integers(1, 100),
    total=st.integers(101, 1000),
    step=st.integers(0, 1000),
)
def test_warmup_cosine_bounds(base, warm, total, step):
    fn = adamw.warmup_cosine(base, warm, total)
    lr = float(fn(jnp.asarray(step)))
    assert 0.0 <= lr <= base * (1 + 1e-6)


# --------------------------------------------------------------------------- #
# chunked attention == naive attention
# --------------------------------------------------------------------------- #
@SET
@given(
    b=st.integers(1, 2),
    hq=st.sampled_from([2, 4]),
    g=st.sampled_from([1, 2]),
    s=st.sampled_from([16, 48, 64]),
    dh=st.sampled_from([8, 16]),
    window=st.sampled_from([None, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_chunked_attention_matches_ref(b, hq, g, s, dh, window, seed):
    from repro.models.layers import _chunked_attention

    hkv = hq // g
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, s, hq, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, dh)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    got = _chunked_attention(
        q, k, v, pos, pos, causal=True, window=window,
        scale=dh**-0.5, chunk=16,
    )
    want = ref.attention(
        jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2),
        causal=True, window=window,
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(jnp.moveaxis(want, 1, 2)),
        atol=2e-5, rtol=2e-5,
    )


# --------------------------------------------------------------------------- #
# paged-attention DMA blocking is a pure perf knob
# --------------------------------------------------------------------------- #
SET_PA = settings(max_examples=8, deadline=None)  # interpret mode is slow


@SET_PA
@given(
    b=st.integers(1, 5),
    hq=st.sampled_from([2, 4, 8]),
    g=st.sampled_from([1, 2]),
    np_=st.integers(1, 5),
    ppb=st.sampled_from([1, 2, 4]),
    bb=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_paged_attention_bitexact_across_blocking(b, hq, g, np_, ppb, bb, seed):
    """``pages_per_block``/``block_b`` tune the kernel's DMA burst shape
    only: any setting must produce BIT-identical output to the default
    (the serving stack retunes them per batch shape, so a single ULP of
    drift would break the preemption replay's exact-token assertion)."""
    from repro.kernels import ops

    hkv = max(1, hq // g)
    d, t = 8, 4
    rng = np.random.default_rng(seed)
    pool_pages = b * np_ + 1
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(pool_pages, t, hkv, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(pool_pages, t, hkv, d)), jnp.float32)
    table = jnp.asarray(
        rng.integers(0, pool_pages, size=(b, np_)), jnp.int32
    )
    lengths = jnp.asarray(rng.integers(0, np_ * t + 1, size=(b,)), jnp.int32)
    base = np.asarray(ops.paged_attention(
        q, kp, vp, table, lengths, impl="pallas"
    ))
    got = np.asarray(ops.paged_attention(
        q, kp, vp, table, lengths, impl="pallas",
        pages_per_block=ppb, block_b=bb,
    ))
    assert got.tobytes() == base.tobytes()


# --------------------------------------------------------------------------- #
# tensor-parallel sharded decode == tp=1 decode
# --------------------------------------------------------------------------- #
SET_TP = settings(max_examples=6, deadline=None)


@SET_TP
@given(
    heads=st.sampled_from([(4, 2, 2), (8, 2, 2), (4, 4, 2), (8, 4, 4),
                           (8, 8, 4)]),
    prompt_len=st.integers(3, 14),
    seed=st.integers(0, 2**31 - 1),
)
def test_tp_sharded_decode_matches_tp1(heads, prompt_len, seed):
    """Head-sharded paged decode (``repro.parallel.tp`` shard rules +
    ``PagedLayout.shard_heads`` + per-sub-block psum) is token-identical
    to ``tp=1`` across random head counts and page-table states: logits
    replicate BITWISE across the group, ``pos`` pool leaves stay bitwise
    equal, and the written k/v pages match to float tolerance (the psum
    reorders each sub-block's reduction, so activations past the first
    block differ from tp=1 in the last bits).

    The group runs as ``jax.vmap(axis_name="tp")`` + ``lax.psum`` — the
    single-device stand-in for the ``shard_map`` the servers use (the
    multi-device path is covered by ``repro.testing.tp_suite``)."""
    import dataclasses

    from jax import lax

    from repro.configs.registry import SMOKE
    from repro.models.build import build_model
    from repro.parallel import tp as tp_lib
    from repro.parallel.ctx import RunCtx
    from repro.serving import pool

    H, KH, TP = heads
    cfg = dataclasses.replace(
        SMOKE["llama3-405b"], n_heads=H, n_kv_heads=KH, head_dim=8,
        n_layers=2, d_model=32, d_ff=64, vocab=64,
    )
    model = build_model(cfg)
    ctx = RunCtx(mesh=None, remat="none")
    params, _ = model.init(ctx, jax.random.PRNGKey(seed % 997))

    rng = np.random.default_rng(seed)
    cache_len, pt, steps = 24, 4, 3
    prompt = rng.integers(0, cfg.vocab, prompt_len).tolist()
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits0, caches = model.prefill(
        params, ctx, {"inputs": toks}, cache_len=cache_len
    )
    t0 = int(np.argmax(np.asarray(logits0)[0]))

    layout = pool.PagedLayout.from_struct(
        model.kv_block_struct(ctx, prompt_len=prompt_len, cache_len=cache_len),
        cache_len=cache_len, page_tokens=pt,
    )
    pages = np.asarray(layout.flatten(caches))
    # random page-table state: logical pages scattered over a larger pool
    n_pool = layout.n_pages + int(rng.integers(1, 4))
    order = rng.permutation(n_pool)[: layout.n_pages]
    mem = np.zeros((n_pool, layout.page_elems), np.float32)
    mem[order] = pages
    table = jnp.asarray(order[None], jnp.int32)

    def decode(run_step, mem_state):
        toks_out, pos, last = [t0], prompt_len, t0
        for _ in range(steps):
            lg, mem_state = run_step(
                mem_state, jnp.asarray([[last]], jnp.int32),
                jnp.asarray([pos], jnp.int32),
            )
            lgn = np.asarray(lg)
            if lgn.ndim == 3:  # stacked (tp, B, vocab): bitwise-replicated
                for s in range(1, TP):
                    assert lgn[s].tobytes() == lgn[0].tobytes()
                lgn = lgn[0]
            last = int(np.argmax(lgn[0]))
            toks_out.append(last)
            pos += 1
        return toks_out, mem_state

    # ---- tp=1 oracle -------------------------------------------------------
    def full_step(mem_state, token, position):
        views = layout.decode_views(mem_state)
        lg, views = model.decode_step_paged(
            params, ctx, token, position, views, table
        )
        return lg, layout.views_to_pool(views)

    full_toks, full_mem = decode(jax.jit(full_step), jnp.asarray(mem))
    full_mem = np.asarray(full_mem)

    # ---- sharded group -----------------------------------------------------
    shard_layout, cols = layout.shard_heads(TP, KH)
    sparams = jax.tree.map(
        jnp.asarray, tp_lib.stack_shards(params, TP)
    )
    group = tp_lib.TPGroup(TP, lambda x: lax.psum(x, "tp"))

    def one_shard(p_shard, mem_shard, token, position):
        vs = shard_layout.decode_views(mem_shard)
        lg, vs = model.decode_step_paged(
            p_shard, ctx, token, position, vs, table, tp=group
        )
        return lg, shard_layout.views_to_pool(vs)

    vstep = jax.jit(jax.vmap(
        one_shard, in_axes=(0, 0, None, None), axis_name="tp"
    ))
    stacked = jnp.asarray(np.stack([mem[:, c] for c in cols]))
    tp_toks, tp_mem = decode(
        lambda m, t, p: vstep(sparams, m, t, p), stacked
    )
    tp_mem = np.asarray(tp_mem)

    assert tp_toks == full_toks
    # pool state leaf-wise: pos bitwise, k/v to float tolerance
    with_path, _ = jax.tree_util.tree_flatten_with_path(
        shard_layout.page_struct()
    )
    for s in range(TP):
        want = full_mem[:, cols[s]]
        for (path, _), leaf in zip(with_path, shard_layout.leaves):
            name = getattr(path[-1], "key", None) if path else None
            sl = slice(leaf.offset, leaf.offset + leaf.size)
            cols_per_page = shard_layout.page_elems
            got_l = tp_mem[s].reshape(-1, cols_per_page)[:, sl]
            want_l = want.reshape(-1, cols_per_page)[:, sl]
            if name in ("k", "v"):
                np.testing.assert_allclose(
                    got_l, want_l, rtol=2e-5, atol=2e-6,
                    err_msg=f"shard {s} leaf {name}",
                )
            else:
                assert got_l.tobytes() == want_l.tobytes(), (
                    f"shard {s} leaf {name} not bitwise"
                )

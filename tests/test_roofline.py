"""Roofline math + registry consistency."""
from repro.configs.registry import runnable_cells
from repro.launch import roofline


def test_model_flops_train_vs_decode():
    t = roofline.model_flops("qwen3-4b", "train_4k")
    d = roofline.model_flops("qwen3-4b", "decode_32k")
    p = roofline.model_flops("qwen3-4b", "prefill_32k")
    # train: 6*N*T tokens; decode: 2*N*B
    assert t / d == (3 * 4096 * 256) / 128
    assert p / d == 32768 * 32 / 128


def test_derive_terms_and_dominance():
    rec = {
        "status": "ok", "arch": "qwen3-4b", "shape": "train_4k",
        "mesh": "single", "tag": "t", "n_devices": 256,
        "cost": {"flops": 1e14, "bytes_accessed": 1e12},
        "collectives": {"per_type": {}, "total": 5e12},
        "memory": {},
    }
    d = roofline.derive(rec)
    assert abs(d["t_compute_s"] - 1e14 / 197e12) < 1e-9
    # memory term is the ANALYTIC minimum-HBM-traffic model (the HLO-text
    # bytes reflect CPU fusion granularity; kept as sched_bytes_dev)
    want_mem = roofline.analytic_memory_bytes("qwen3-4b", "train_4k", 256)
    assert abs(d["t_memory_s"] - want_mem / 819e9) < 1e-9
    assert d["sched_bytes_dev"] == 1e12
    assert abs(d["t_collective_s"] - 5e12 / 50e9) < 1e-9
    assert d["dominant"] == "collective"
    assert 0 < d["roofline_fraction"] <= 1.5


def test_runnable_cells_count():
    cells = runnable_cells()
    # 10 archs x 4 shapes - 7 long_500k skips = 33
    assert len(cells) == 33
    assert ("llama3-405b", "long_500k") not in cells
    assert ("falcon-mamba-7b", "long_500k") in cells

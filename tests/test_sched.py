"""Collective scheduler: planner, cost model, engine map, batch waits.

Single-device tests: the planner and the engine map are host-side
objects; execution paths are covered by the lockstep simulator here and
by the multi-device suites (``tests/test_multidev.py``).
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import collectives, sched
from repro.core.engine import (
    AlreadyWaitedError,
    EngineMap,
    GascoreEngine,
    Pending,
    XlaEngine,
    make_engine,
    parse_backend_spec,
    wait_all,
)
from repro.testing.sim import run_spmd


# --------------------------------------------------------------------------- #
# size-aware algorithm selection
# --------------------------------------------------------------------------- #
def test_small_allreduce_takes_recursive_doubling():
    p = sched.plan_collective("all_reduce", nbytes=1 << 10, n_nodes=8)
    assert p.algorithm == "recursive_doubling"
    assert "latency" in p.reason


def test_large_allreduce_takes_segmented_ring():
    p = sched.plan_collective("all_reduce", nbytes=64 << 20, n_nodes=8)
    assert p.algorithm == "ring"
    assert p.n_segments > 1
    assert p.depth >= 2


def test_non_pow2_never_recursive_doubling():
    for nbytes in (64, 1 << 14, 1 << 24):
        p = sched.plan_collective("all_reduce", nbytes=nbytes, n_nodes=6)
        assert p.algorithm == "ring"


def test_small_broadcast_takes_tree_only_with_partial_permute():
    sw = XlaEngine("node", 8)
    hw = GascoreEngine("node", 8)
    assert sched.plan_collective(
        "broadcast", nbytes=256, n_nodes=8, engine=sw
    ).algorithm == "tree"
    assert sched.plan_collective(
        "broadcast", nbytes=256, n_nodes=8, engine=hw
    ).algorithm == "ring"


def test_all_to_all_native_vs_direct():
    sw = XlaEngine("node", 8)
    hw = GascoreEngine("node", 8)
    assert sched.plan_collective(
        "all_to_all", nbytes=1 << 12, n_nodes=8, engine=sw
    ).algorithm == "native"
    assert sched.plan_collective(
        "all_to_all", nbytes=1 << 12, n_nodes=8, engine=hw
    ).algorithm == "direct"


def test_explicit_segments_pin_the_plan():
    p = sched.plan_collective(
        "all_gather", nbytes=1 << 24, n_nodes=4, n_segments=5, depth=3
    )
    assert (p.n_segments, p.depth) == (5, 3)


def test_pinned_segments_force_the_ring_algorithm():
    # a caller asking for segments is asking for the segmented ring, even
    # at payload sizes where the latency tier would otherwise win
    p = sched.plan_collective(
        "all_reduce", nbytes=32, n_nodes=4, n_segments=2, depth=2
    )
    assert p.algorithm == "ring"
    assert (p.n_segments, p.depth) == (2, 2)
    b = sched.plan_collective("broadcast", nbytes=32, n_nodes=4, depth=2)
    assert b.algorithm == "ring"


def test_single_node_plan_is_free():
    p = sched.plan_collective("all_reduce", nbytes=1 << 20, n_nodes=1)
    assert p.est_us == 0.0


def test_plan_describe_names_algorithm_and_size():
    p = sched.plan_collective("all_reduce", nbytes=4096, n_nodes=8)
    s = p.describe()
    assert p.algorithm in s and "4096B" in s


def test_plan_p2p_segments_large_boundary():
    small = sched.plan_p2p(nbytes=4 << 10)
    large = sched.plan_p2p(nbytes=8 << 20)
    assert small.n_segments == 1
    assert large.n_segments > 1


def test_unknown_op_rejected():
    with pytest.raises(ValueError):
        sched.plan_collective("scan", nbytes=1, n_nodes=2)


# --------------------------------------------------------------------------- #
# cost model
# --------------------------------------------------------------------------- #
def test_load_costs_roundtrip(tmp_path):
    path = tmp_path / "BENCH_gas.json"
    path.write_text(json.dumps({
        "engine_costs": {
            "xla": {"alpha_us": 7.0, "beta_us_per_kib": 0.5,
                    "gamma_us_per_kib": 0.25},
        }
    }))
    costs = sched.load_costs(str(path))
    assert costs["xla"].alpha_us == 7.0
    assert "gascore" in costs  # defaults retained


def test_load_costs_missing_file_falls_back(tmp_path):
    costs = sched.load_costs(str(tmp_path / "nope.json"))
    assert costs == sched.DEFAULT_COSTS


def test_engine_map_plans_against_worst_member():
    m = EngineMap("node", ("xla", "gascore", "xla", "gascore"))
    c = sched.cost_of(m)
    cx, cg = sched.DEFAULT_COSTS["xla"], sched.DEFAULT_COSTS["gascore"]
    assert c.alpha_us == max(cx.alpha_us, cg.alpha_us)


def test_load_costs_reads_engine_pair_costs(tmp_path):
    path = tmp_path / "BENCH_gas.json"
    path.write_text(json.dumps({
        "engine_pair_costs": {
            "xla->gascore": {"alpha_us": 55.0, "beta_us_per_kib": 0.9,
                             "gamma_us_per_kib": 0.3},
            "gascore->xla": {"alpha_us": 60.0, "beta_us_per_kib": 0.7},
        }
    }))
    costs = sched.load_costs(str(path))
    assert costs["xla->gascore"].alpha_us == 55.0
    assert costs["gascore->xla"].beta_us_per_kib == 0.7
    assert "xla" in costs  # per-engine defaults retained alongside pairs


def test_engine_map_prefers_measured_pair_costs():
    m = EngineMap("node", ("xla", "gascore", "xla", "gascore"))
    table = dict(sched.DEFAULT_COSTS)
    table["xla->gascore"] = sched.EngineCost(100.0, 2.0, 0.5)
    table["gascore->xla"] = sched.EngineCost(90.0, 3.0, 0.4)
    c = sched.cost_of(m, table)
    # the worst measured edge paces the group, not the analytic worst member
    assert c.alpha_us == 100.0 and c.beta_us_per_kib == 3.0


def test_engine_map_missing_pair_falls_back_to_analytic():
    # one direction measured, the other absent: plan_collective must not
    # KeyError — it degrades to the analytic worst-member model
    m = EngineMap("node", ("xla", "gascore", "xla", "gascore"))
    table = dict(sched.DEFAULT_COSTS)
    table["xla->gascore"] = sched.EngineCost(100.0, 2.0, 0.5)
    c = sched.cost_of(m, table)
    cx, cg = table["xla"], table["gascore"]
    assert c.alpha_us == max(cx.alpha_us, cg.alpha_us)
    p = sched.plan_collective(
        "all_reduce", nbytes=1 << 12, n_nodes=4, engine=m, costs=table
    )
    assert p.est_us > 0.0  # planned, not crashed


def test_homogeneous_map_ignores_pair_costs():
    m = EngineMap("node", ("xla", "xla"))
    table = dict(sched.DEFAULT_COSTS)
    table["xla->gascore"] = sched.EngineCost(999.0, 9.0, 9.0)
    assert sched.cost_of(m, table).alpha_us == table["xla"].alpha_us


# --------------------------------------------------------------------------- #
# heterogeneous node map construction
# --------------------------------------------------------------------------- #
def test_parse_backend_spec_tiles_patterns():
    assert parse_backend_spec("xla", 4) == ("xla",) * 4
    assert parse_backend_spec("xla,gascore", 4) == (
        "xla", "gascore", "xla", "gascore"
    )
    assert parse_backend_spec(["gascore", "xla"], 2) == ("gascore", "xla")
    with pytest.raises(ValueError):
        parse_backend_spec("xla,gascore,xla", 4)  # does not tile
    with pytest.raises(ValueError):
        parse_backend_spec("", 4)


def test_make_engine_returns_map_only_when_mixed():
    assert isinstance(make_engine("xla", "node", 4), XlaEngine)
    assert isinstance(make_engine("gascore,gascore", "node", 4), GascoreEngine)
    m = make_engine("xla,gascore", "node", 4)
    assert isinstance(m, EngineMap)
    assert m.is_heterogeneous
    assert m.backend_of(0) == "xla" and m.backend_of(1) == "gascore"


def test_engine_map_capabilities_are_conjunction():
    mixed = EngineMap("node", ("xla", "gascore"))
    soft = EngineMap("node", ("xla", "xla"))
    assert not mixed.can_permute_partial  # gascore is bijection-only
    assert soft.can_permute_partial


def test_node_backends_patterns():
    from repro.launch.mesh import node_backends

    assert node_backends(4) == ("xla",) * 4
    assert node_backends(4, pattern="alternating") == (
        "xla", "gascore", "xla", "gascore"
    )
    assert node_backends(4, pattern="split") == (
        "xla", "xla", "gascore", "gascore"
    )
    assert node_backends(4, hw_ranks=[0]) == (
        "gascore", "xla", "xla", "xla"
    )
    with pytest.raises(ValueError):
        node_backends(4, hw_ranks=[0], pattern="split")
    with pytest.raises(ValueError):
        node_backends(4, pattern="zebra")


# --------------------------------------------------------------------------- #
# Pending / batch waits (Extended API engine half)
# --------------------------------------------------------------------------- #
def test_pending_double_wait_names_op():
    p = Pending(jnp.ones(3), op="shift(k=2)")
    p.wait()
    with pytest.raises(AlreadyWaitedError, match=r"shift\(k=2\)"):
        p.wait()


def test_wait_all_rejects_stale_handle_before_draining():
    p1 = Pending(jnp.ones(2), op="shift(k=1)")
    p2 = Pending(jnp.ones(2), op="permute")
    p1.wait()
    with pytest.raises(AlreadyWaitedError, match=r"#0 \(shift\(k=1\)\)"):
        wait_all([p1, p2])
    assert not p2.waited  # batch left intact, not half-drained
    got = wait_all([p2])
    assert len(got) == 1


def test_extended_handle_error_is_same_type():
    from repro.core import extended

    h = extended.GetHandle(jnp.zeros(1))
    h.complete()
    with pytest.raises(AlreadyWaitedError, match="get"):
        h.complete()


# --------------------------------------------------------------------------- #
# segment bounds
# --------------------------------------------------------------------------- #
def test_segment_bounds_partition_exactly():
    for m in (1, 2, 7, 16, 33):
        for g in (1, 2, 3, 8, 64):
            bounds = collectives.segment_bounds(m, g)
            assert bounds[0][0] == 0 and bounds[-1][1] == m
            for (lo, hi), (lo2, _) in zip(bounds, bounds[1:]):
                assert hi == lo2 and hi > lo
            assert len(bounds) == min(g, m)
            sizes = [hi - lo for lo, hi in bounds]
            assert max(sizes) - min(sizes) <= 1


# --------------------------------------------------------------------------- #
# planned execution through the lockstep simulator (single device)
# --------------------------------------------------------------------------- #
def test_sched_all_reduce_dispatch_matches_sum():
    n = 4
    xs = [jnp.asarray(np.arange(8) + 10 * r, jnp.int32) for r in range(n)]
    want = np.sum([np.asarray(x) for x in xs], axis=0)
    # small payload on pow2 sim engine -> recursive doubling path
    outs = run_spmd(lambda e: sched.all_reduce(e, xs[e.rank]), n)
    for o in outs:
        np.testing.assert_array_equal(np.asarray(o), want)
    # pinned segmented-ring path (pins force the ring algorithm)
    ring_plan = sched.plan_collective(
        "all_reduce", nbytes=32, n_nodes=n, n_segments=2, depth=2
    )
    assert ring_plan.algorithm == "ring"
    outs = run_spmd(lambda e: sched.all_reduce(e, xs[e.rank], plan=ring_plan), n)
    for o in outs:
        np.testing.assert_array_equal(np.asarray(o), want)


def test_sched_broadcast_tree_path():
    n = 8
    xs = [jnp.full((5,), r, jnp.int32) for r in range(n)]
    outs = run_spmd(lambda e: sched.broadcast(e, xs[e.rank], root=3), n)
    for o in outs:
        np.testing.assert_array_equal(np.asarray(o), 3)

"""Disaggregated serving: AM request/reply plane + KV-block data plane.

Fast tests run the GAS programs on the single-device lockstep simulator
(``repro.testing.sim``) and validate the KV block layout against real
model caches; the slow test runs the end-to-end example (distinct
prefill/decode ranks, plan_p2p-segmented puts, AM-reply acks) in a
subprocess with forced host devices.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import am, gasnet
from repro.serving import kv
from repro.serving import pool
from repro.testing.sim import run_spmd

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --------------------------------------------------------------------------- #
# AM request/reply round trip (lockstep simulator, single device)
# --------------------------------------------------------------------------- #
def _pingpong_table():
    table = am.HandlerTable()

    def pong(state, payload, args):
        out = dict(state)
        out["ack_payload"] = payload
        out["ack_arg"] = state["ack_arg"] + args[0]
        return out

    pong_id = table.register("pong", pong)

    def ping(state, payload, args):
        out = dict(state)
        out["got"] = state["got"] + args[0]
        reply = am.reply_medium(pong_id, payload + 1.0, args=(args[0] + 1,))
        return out, reply

    table.register("ping", ping, replies=True)
    return table


@pytest.mark.parametrize("n,shift", [(2, 1), (5, 3), (8, 5)])
def test_am_request_reply_round_trip(n, shift):
    def program(engine):
        node = gasnet.Node(
            engine,
            _pingpong_table(),
            am_capacity=8,
            am_payload_width=4,
            am_per_peer_capacity=8,
        )
        me = node.my_id
        state = {
            "got": jnp.zeros((), jnp.int32),
            "ack_arg": jnp.zeros((), jnp.int32),
            "ack_payload": jnp.zeros((4,), jnp.float32),
        }
        handle = node.am_call(
            (me + shift) % n,
            "ping",
            payload=jnp.full((4,), me, jnp.float32),
            args=(me * 10,),
            ack=lambda st: st["ack_payload"],
        )
        state = node.am_flush(state)
        return state["got"], state["ack_arg"], node.sync(handle)

    outs = run_spmd(program, n)
    for rank, (got, ack_arg, ack_payload) in enumerate(outs):
        # request hop: handler ran at rank (me + shift) % n
        assert int(got) == ((rank - shift) % n) * 10
        # reply hop: the AMReply came back to the requester
        assert int(ack_arg) == rank * 10 + 1
        np.testing.assert_allclose(np.asarray(ack_payload), rank + 1.0)


def test_am_call_requires_replying_handler():
    table = am.HandlerTable()
    table.register("plain", lambda s, p, a: s)

    def program(engine):
        node = gasnet.Node(
            engine, table, am_capacity=4, am_payload_width=2, am_per_peer_capacity=4
        )
        with pytest.raises(ValueError, match="replying"):
            node.am_call(jnp.zeros((), jnp.int32), "plain")
        return jnp.zeros(())

    run_spmd(program, 2)


def test_ack_handle_sync_before_flush_raises():
    table = _pingpong_table()

    def program(engine):
        node = gasnet.Node(
            engine, table, am_capacity=4, am_payload_width=4, am_per_peer_capacity=4
        )
        handle = node.am_call(
            jnp.zeros((), jnp.int32),
            "ping",
            payload=jnp.zeros((4,), jnp.float32),
            ack=lambda st: st["ack_arg"],
        )
        with pytest.raises(RuntimeError, match="before am_flush"):
            node.sync(handle)
        return jnp.zeros(())

    run_spmd(program, 2)


# --------------------------------------------------------------------------- #
# KV-block data plane (simulator)
# --------------------------------------------------------------------------- #
def _kv_push_ranks(n, block, n_segments, n_slots=2, slot=1, gate=None):
    """Every rank pushes its block to rank (me+1) % n, segmented."""
    rng = np.random.default_rng(block + n)
    blocks = [jnp.asarray(rng.normal(size=(block,)), jnp.float32) for _ in range(n)]

    def program(engine):
        node = gasnet.Node(
            engine,
            am.HandlerTable(),
            am_capacity=4,
            am_payload_width=1,
            am_per_peer_capacity=4,
        )
        seg = jnp.zeros((1, n_slots * block), jnp.float32)
        pred = None if gate is None else gate[engine.rank]
        handles, plan = kv.push_block(
            node,
            seg,
            blocks[engine.rank],
            to=gasnet.Shift(1),
            base_index=slot * block,
            pred=pred,
            n_segments=n_segments,
        )
        assert plan.op == "p2p"
        seg = kv.sync_push(node, seg, handles)
        return seg

    return blocks, run_spmd(program, n)


@pytest.mark.parametrize("n,block,g", [(2, 7, 1), (3, 16, 4), (4, 33, 5)])
def test_segmented_kv_push_lands_whole_block(n, block, g):
    blocks, segs = _kv_push_ranks(n, block, g)
    for rank, seg in enumerate(segs):
        got = np.asarray(seg)[0]
        np.testing.assert_array_equal(got[block:], np.asarray(blocks[(rank - 1) % n]))
        np.testing.assert_array_equal(got[:block], 0.0)


def test_segmented_matches_monolithic_push():
    _, mono = _kv_push_ranks(3, 24, 1)
    _, seg = _kv_push_ranks(3, 24, 6)
    for a, b in zip(mono, seg):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pred_gated_push_leaves_receiver_untouched():
    n = 4
    gate = [r % 2 == 0 for r in range(n)]  # only even ranks send
    blocks, segs = _kv_push_ranks(n, 8, 3, gate=gate)
    for rank, seg in enumerate(segs):
        got = np.asarray(seg)[0, 8:]
        sender = (rank - 1) % n
        if gate[sender]:
            np.testing.assert_array_equal(got, np.asarray(blocks[sender]))
        else:
            np.testing.assert_array_equal(got, 0.0)


def test_handoff_permutation_completes_bijection():
    perm = kv.handoff_permutation(6, {0: 4, 1: 3})
    assert sorted(perm) == list(range(6))
    assert perm[0] == 4 and perm[1] == 3
    with pytest.raises(ValueError, match="duplicate destination"):
        kv.handoff_permutation(4, {0: 2, 1: 2})


def test_segment_bounds_cover_exactly():
    for total, g in [(1, 1), (7, 3), (12, 12), (10, 64)]:
        bounds = kv.segment_bounds(total, g)
        assert bounds[0][0] == 0
        assert sum(size for _, size in bounds) == total
        for (off_a, size_a), (off_b, _) in zip(bounds, bounds[1:]):
            assert off_a + size_a == off_b
        assert all(size > 0 for _, size in bounds)


# --------------------------------------------------------------------------- #
# KV layout: bit-exact round trip of real model caches
# --------------------------------------------------------------------------- #
def test_kv_layout_round_trips_model_cache():
    from repro.configs.registry import SMOKE
    from repro.models.build import build_model
    from repro.parallel.ctx import RunCtx

    cfg = SMOKE["qwen3-4b"]
    model = build_model(cfg)
    ctx = RunCtx(mesh=None, remat="none")
    params, _ = model.init(ctx, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    _, caches = model.prefill(params, ctx, {"inputs": toks}, cache_len=32)

    layout = kv.KVLayout.from_struct(
        model.kv_block_struct(ctx, prompt_len=8, cache_len=32)
    )
    flat = layout.flatten(caches)
    assert flat.shape == (layout.total,) and flat.dtype == jnp.float32
    restored = layout.unflatten(flat)

    ref_leaves = jax.tree_util.tree_leaves(caches)
    got_leaves = jax.tree_util.tree_leaves(restored)
    assert len(ref_leaves) == len(got_leaves)
    for a, b in zip(ref_leaves, got_leaves):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_kv_layout_shapes_independent_of_prompt_len():
    from repro.configs.registry import SMOKE
    from repro.models.build import build_model
    from repro.parallel.ctx import RunCtx

    cfg = SMOKE["qwen3-4b"]
    model = build_model(cfg)
    ctx = RunCtx(mesh=None, remat="none")
    struct_a = model.kv_block_struct(ctx, prompt_len=4, cache_len=32)
    struct_b = model.kv_block_struct(ctx, prompt_len=19, cache_len=32)
    a = kv.KVLayout.from_struct(struct_a)
    b = kv.KVLayout.from_struct(struct_b)
    assert a.total == b.total
    assert [leaf.shape for leaf in a.leaves] == [leaf.shape for leaf in b.leaves]


# --------------------------------------------------------------------------- #
# paged KV pool: layout, allocator, store, vectored page fetch
# --------------------------------------------------------------------------- #
def _smoke_model():
    from repro.configs.registry import SMOKE
    from repro.models.build import build_model
    from repro.parallel.ctx import RunCtx

    cfg = SMOKE["qwen3-4b"]
    model = build_model(cfg)
    ctx = RunCtx(mesh=None, remat="none")
    return cfg, model, ctx


def test_paged_layout_round_trips_model_cache():
    cfg, model, ctx = _smoke_model()
    params, _ = model.init(ctx, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    _, caches = model.prefill(params, ctx, {"inputs": toks}, cache_len=32)

    layout = pool.PagedLayout.from_struct(
        model.kv_block_struct(ctx, prompt_len=8, cache_len=32),
        cache_len=32,
        page_tokens=8,
    )
    assert layout.n_pages == 4
    pages = layout.flatten(caches)
    assert pages.shape == (4, layout.page_elems)
    restored = layout.unflatten(pages)
    for a, b in zip(jax.tree.leaves(caches), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # paged and dense flattenings carry the same payload volume
    dense = kv.KVLayout.from_struct(
        model.kv_block_struct(ctx, prompt_len=8, cache_len=32)
    )
    assert layout.n_pages * layout.page_elems == dense.total


def test_kv_page_struct_matches_layout():
    _, model, ctx = _smoke_model()
    page_struct, n_pages = model.kv_page_struct(ctx, cache_len=32, page_tokens=8)
    layout = pool.PagedLayout.from_struct(
        model.kv_block_struct(ctx, prompt_len=4, cache_len=32),
        cache_len=32,
        page_tokens=8,
    )
    assert n_pages == layout.n_pages
    per_page = sum(int(np.prod(leaf.shape)) for leaf in jax.tree.leaves(page_struct))
    assert per_page == layout.page_elems
    with pytest.raises(ValueError, match="not a multiple"):
        model.kv_page_struct(ctx, cache_len=32, page_tokens=5)


def test_pool_allocator_refcounts_and_cow():
    st = pool.make_pool(4)
    st, a = pool.alloc(st, 2)
    st = pool.fork(st, (a[0],))  # shared prefix page
    st = pool.free(st, a)  # a[0] still live (refcount 1), a[1] free
    pool.check_pool(st)
    assert st.n_free == 3
    st, fresh, copied = pool.writable(st, a[0])
    assert not copied and fresh == a[0]
    st = pool.fork(st, (a[0],))
    st, fresh, copied = pool.writable(st, a[0])
    assert copied and fresh != a[0]  # copy-on-write split
    pool.check_pool(st)
    st = pool.free(st, (a[0], fresh))
    pool.check_pool(st)
    assert st.n_free == 4
    with pytest.raises(pool.DoubleFreeError):
        pool.free(st, (a[0],))
    with pytest.raises(pool.OutOfPagesError):
        pool.alloc(st, 5)


def test_store_prefix_sharing_resolves_same_physical_pages():
    _, model, ctx = _smoke_model()
    layout = pool.PagedLayout.from_struct(
        model.kv_block_struct(ctx, prompt_len=4, cache_len=32),
        cache_len=32,
        page_tokens=8,
    )
    store = pool.PagedKVStore(layout, 12)
    rng = np.random.default_rng(0)
    pages = rng.normal(size=(layout.n_pages, layout.page_elems)).astype(np.float32)
    shared = list(range(100, 120))  # 2 full pages + a partial third
    p1 = store.admit(1, shared, pages)
    p2 = store.admit(2, shared + [7], pages)
    # two prefix-sharing requests map the SAME physical pages
    assert p1.table[:2] == p2.table[:2]
    assert not p2.fresh[0] and not p2.fresh[1]
    # the partial boundary page is private
    assert p1.table[2] != p2.table[2]
    assert store.prefix_match(shared) == 2
    store.release(1)
    assert store.prefix_match(shared) == 2  # rid 2 keeps the pages live
    store.release(2)
    assert store.prefix_match(shared) == 0  # last ref dropped the index
    assert store.n_free == 12
    pool.check_pool(store.state)


def test_fetch_pages_vectored_get_round_trip():
    """Each rank prefetches 3 pages from its neighbour's pool shard with
    the split-phase vectored get; the fetched carrier rows must equal the
    owner's pages (lockstep simulator, both batch counts)."""
    n, pages_per_rank, page_elems = 3, 4, 6
    rng = np.random.default_rng(1)
    shards = [
        jnp.asarray(rng.normal(size=(pages_per_rank * page_elems,)), jnp.float32)
        for _ in range(n)
    ]
    pmap = pool.PoolMap(n, pages_per_rank, page_elems)
    want_pages = (3, 0, 2)

    def make_program(n_batches):
        def program(engine):
            node = gasnet.Node(
                engine,
                am.HandlerTable(),
                am_capacity=4,
                am_payload_width=1,
                am_per_peer_capacity=4,
            )
            seg = shards[engine.rank][None]
            offsets = [pmap.offset(p) for p in want_pages]
            handles, plan = pool.fetch_pages(
                node,
                seg,
                jnp.stack(offsets),
                frm=gasnet.Shift(1),
                page_elems=page_elems,
                n_batches=n_batches,
            )
            assert plan.op == "p2p"
            return pool.sync_fetch(node, handles)

        return program

    for g in (1, 3):
        outs = run_spmd(make_program(g), n)
        for rank, got in enumerate(outs):
            owner = (rank + 1) % n
            want = np.asarray(shards[owner]).reshape(pages_per_rank, page_elems)[
                list(want_pages)
            ]
            np.testing.assert_array_equal(np.asarray(got), want)


def test_get_nbv_pred_gated(n=4):
    """Vectored get with pred=False completes to zeros (SPMD conditional
    fetch) while gated-true ranks receive the remote slices."""

    def program(engine):
        node = gasnet.Node(
            engine,
            am.HandlerTable(),
            am_capacity=4,
            am_payload_width=1,
            am_per_peer_capacity=4,
        )
        seg = (jnp.arange(8, dtype=jnp.float32) + 10 * engine.rank)[None]
        h = node.get_nbv(
            seg,
            frm=gasnet.Shift(1),
            indices=[0, 4],
            size=2,
            pred=engine.rank % 2 == 0,
        )
        return node.sync(h)

    outs = run_spmd(program, n)
    for rank, got in enumerate(outs):
        got = np.asarray(got)
        if rank % 2 == 0:
            src = (rank + 1) % n
            want = np.asarray([[0.0, 1.0], [4.0, 5.0]]) + 10 * src
            np.testing.assert_array_equal(got, want)
        else:
            np.testing.assert_array_equal(got, 0.0)


def test_paged_server_token_parity_and_pool_drain():
    """Colocated paged server: tokens identical to the dense server, two
    prefix-sharing requests resolve to shared physical pages, and every
    page is freed when its request retires."""
    from repro.launch.serve import PagedServer, Request, Server

    cfg, model, ctx = _smoke_model()
    params, _ = model.init(ctx, jax.random.PRNGKey(0))

    def burst():
        rng = np.random.default_rng(3)
        shared = rng.integers(0, cfg.vocab, size=16).tolist()
        reqs = [
            Request(rid=0, prompt=shared + [5], max_new=4),
            Request(rid=1, prompt=shared + [9, 11], max_new=4),
            Request(
                rid=2,
                prompt=rng.integers(0, cfg.vocab, size=7).tolist(),
                max_new=5,
            ),
        ]
        return reqs

    dense = Server(model, ctx, params, 2, 32)
    for r in burst():
        dense.submit(r)
    dense.run_until_drained()

    paged = PagedServer(model, ctx, params, 2, 32, page_tokens=8)
    for r in burst():
        paged.submit(r)
    stats = paged.run_until_drained()

    base = {r.rid: r.out for r in dense.finished}
    got = {r.rid: r.out for r in paged.finished}
    assert base.keys() == got.keys()
    for rid in base:
        assert base[rid] == got[rid], (rid, base[rid], got[rid])
    # rid 0/1 share 16 prompt tokens = 2 physical pages
    assert stats["pool_prefix_hits"] >= 2
    # allocator fully drained: no leaked pages
    assert stats["pool_n_free"] == stats["pool_n_pages"]
    pool.check_pool(paged.store.state)


# --------------------------------------------------------------------------- #
# end-to-end: the example's prefill -> KV put -> decode round trip
# --------------------------------------------------------------------------- #
@pytest.mark.slow
def test_disagg_serve_example_smoke():
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(ROOT, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    cmd = [
        sys.executable,
        os.path.join(ROOT, "examples", "serve_requests.py"),
        "--smoke",
    ]
    proc = subprocess.run(
        cmd, capture_output=True, text=True, timeout=1200, env=env, cwd=ROOT
    )
    assert proc.returncode == 0, proc.stdout[-4000:] + proc.stderr[-4000:]
    # KV transfer planned by plan_p2p...
    assert "kv plan: p2p[" in proc.stdout
    # ...acknowledged via an AM reply...
    assert "acked via AM reply: 6" in proc.stdout
    # ...across distinct prefill/decode ranks, token-identical to the
    # colocated baseline
    assert "parity: disaggregated tokens == colocated tokens" in proc.stdout
    # ...and the paged act: pages land in the pool, the prefix-sharing
    # pair maps shared physical pages, tokens stay identical
    assert "prefix-shared pages mapped not moved" in proc.stdout
    assert "parity: paged tokens == dense tokens" in proc.stdout
    assert "DISAGG_SERVE_PASS" in proc.stdout

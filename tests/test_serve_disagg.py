"""Disaggregated serving: AM request/reply plane + KV-block data plane.

Fast tests run the GAS programs on the single-device lockstep simulator
(``repro.testing.sim``) and validate the KV block layout against real
model caches; the slow test runs the end-to-end example (distinct
prefill/decode ranks, plan_p2p-segmented puts, AM-reply acks) in a
subprocess with forced host devices.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import am, gasnet
from repro.serving import kv
from repro.serving import pool
from repro.testing.sim import run_spmd

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --------------------------------------------------------------------------- #
# AM request/reply round trip (lockstep simulator, single device)
# --------------------------------------------------------------------------- #
def _pingpong_table():
    table = am.HandlerTable()

    def pong(state, payload, args):
        out = dict(state)
        out["ack_payload"] = payload
        out["ack_arg"] = state["ack_arg"] + args[0]
        return out

    pong_id = table.register("pong", pong)

    def ping(state, payload, args):
        out = dict(state)
        out["got"] = state["got"] + args[0]
        reply = am.reply_medium(pong_id, payload + 1.0, args=(args[0] + 1,))
        return out, reply

    table.register("ping", ping, replies=True)
    return table


@pytest.mark.parametrize("n,shift", [(2, 1), (5, 3), (8, 5)])
def test_am_request_reply_round_trip(n, shift):
    def program(engine):
        node = gasnet.Node(
            engine,
            _pingpong_table(),
            am_capacity=8,
            am_payload_width=4,
            am_per_peer_capacity=8,
        )
        me = node.my_id
        state = {
            "got": jnp.zeros((), jnp.int32),
            "ack_arg": jnp.zeros((), jnp.int32),
            "ack_payload": jnp.zeros((4,), jnp.float32),
        }
        handle = node.am_call(
            (me + shift) % n,
            "ping",
            payload=jnp.full((4,), me, jnp.float32),
            args=(me * 10,),
            ack=lambda st: st["ack_payload"],
        )
        state = node.am_flush(state)
        return state["got"], state["ack_arg"], node.sync(handle)

    outs = run_spmd(program, n)
    for rank, (got, ack_arg, ack_payload) in enumerate(outs):
        # request hop: handler ran at rank (me + shift) % n
        assert int(got) == ((rank - shift) % n) * 10
        # reply hop: the AMReply came back to the requester
        assert int(ack_arg) == rank * 10 + 1
        np.testing.assert_allclose(np.asarray(ack_payload), rank + 1.0)


def test_am_call_requires_replying_handler():
    table = am.HandlerTable()
    table.register("plain", lambda s, p, a: s)

    def program(engine):
        node = gasnet.Node(
            engine, table, am_capacity=4, am_payload_width=2, am_per_peer_capacity=4
        )
        with pytest.raises(ValueError, match="replying"):
            node.am_call(jnp.zeros((), jnp.int32), "plain")
        return jnp.zeros(())

    run_spmd(program, 2)


def test_ack_handle_sync_before_flush_raises():
    table = _pingpong_table()

    def program(engine):
        node = gasnet.Node(
            engine, table, am_capacity=4, am_payload_width=4, am_per_peer_capacity=4
        )
        handle = node.am_call(
            jnp.zeros((), jnp.int32),
            "ping",
            payload=jnp.zeros((4,), jnp.float32),
            ack=lambda st: st["ack_arg"],
        )
        with pytest.raises(RuntimeError, match="before am_flush"):
            node.sync(handle)
        return jnp.zeros(())

    run_spmd(program, 2)


# --------------------------------------------------------------------------- #
# KV-block data plane (simulator)
# --------------------------------------------------------------------------- #
def _kv_push_ranks(n, block, n_segments, n_slots=2, slot=1, gate=None):
    """Every rank pushes its block to rank (me+1) % n, segmented."""
    rng = np.random.default_rng(block + n)
    blocks = [jnp.asarray(rng.normal(size=(block,)), jnp.float32) for _ in range(n)]

    def program(engine):
        node = gasnet.Node(
            engine,
            am.HandlerTable(),
            am_capacity=4,
            am_payload_width=1,
            am_per_peer_capacity=4,
        )
        seg = jnp.zeros((1, n_slots * block), jnp.float32)
        pred = None if gate is None else gate[engine.rank]
        handles, plan = kv.push_block(
            node,
            seg,
            blocks[engine.rank],
            to=gasnet.Shift(1),
            base_index=slot * block,
            pred=pred,
            n_segments=n_segments,
        )
        assert plan.op == "p2p"
        seg = kv.sync_push(node, seg, handles)
        return seg

    return blocks, run_spmd(program, n)


@pytest.mark.parametrize("n,block,g", [(2, 7, 1), (3, 16, 4), (4, 33, 5)])
def test_segmented_kv_push_lands_whole_block(n, block, g):
    blocks, segs = _kv_push_ranks(n, block, g)
    for rank, seg in enumerate(segs):
        got = np.asarray(seg)[0]
        np.testing.assert_array_equal(got[block:], np.asarray(blocks[(rank - 1) % n]))
        np.testing.assert_array_equal(got[:block], 0.0)


def test_segmented_matches_monolithic_push():
    _, mono = _kv_push_ranks(3, 24, 1)
    _, seg = _kv_push_ranks(3, 24, 6)
    for a, b in zip(mono, seg):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pred_gated_push_leaves_receiver_untouched():
    n = 4
    gate = [r % 2 == 0 for r in range(n)]  # only even ranks send
    blocks, segs = _kv_push_ranks(n, 8, 3, gate=gate)
    for rank, seg in enumerate(segs):
        got = np.asarray(seg)[0, 8:]
        sender = (rank - 1) % n
        if gate[sender]:
            np.testing.assert_array_equal(got, np.asarray(blocks[sender]))
        else:
            np.testing.assert_array_equal(got, 0.0)


def test_handoff_permutation_completes_bijection():
    perm = kv.handoff_permutation(6, {0: 4, 1: 3})
    assert sorted(perm) == list(range(6))
    assert perm[0] == 4 and perm[1] == 3
    with pytest.raises(ValueError, match="duplicate destination"):
        kv.handoff_permutation(4, {0: 2, 1: 2})


def test_segment_bounds_cover_exactly():
    for total, g in [(1, 1), (7, 3), (12, 12), (10, 64)]:
        bounds = kv.segment_bounds(total, g)
        assert bounds[0][0] == 0
        assert sum(size for _, size in bounds) == total
        for (off_a, size_a), (off_b, _) in zip(bounds, bounds[1:]):
            assert off_a + size_a == off_b
        assert all(size > 0 for _, size in bounds)


# --------------------------------------------------------------------------- #
# KV layout: bit-exact round trip of real model caches
# --------------------------------------------------------------------------- #
def test_kv_layout_round_trips_model_cache():
    from repro.configs.registry import SMOKE
    from repro.models.build import build_model
    from repro.parallel.ctx import RunCtx

    cfg = SMOKE["qwen3-4b"]
    model = build_model(cfg)
    ctx = RunCtx(mesh=None, remat="none")
    params, _ = model.init(ctx, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    _, caches = model.prefill(params, ctx, {"inputs": toks}, cache_len=32)

    layout = kv.KVLayout.from_struct(
        model.kv_block_struct(ctx, prompt_len=8, cache_len=32)
    )
    flat = layout.flatten(caches)
    assert flat.shape == (layout.total,) and flat.dtype == jnp.float32
    restored = layout.unflatten(flat)

    ref_leaves = jax.tree_util.tree_leaves(caches)
    got_leaves = jax.tree_util.tree_leaves(restored)
    assert len(ref_leaves) == len(got_leaves)
    for a, b in zip(ref_leaves, got_leaves):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_kv_layout_shapes_independent_of_prompt_len():
    from repro.configs.registry import SMOKE
    from repro.models.build import build_model
    from repro.parallel.ctx import RunCtx

    cfg = SMOKE["qwen3-4b"]
    model = build_model(cfg)
    ctx = RunCtx(mesh=None, remat="none")
    struct_a = model.kv_block_struct(ctx, prompt_len=4, cache_len=32)
    struct_b = model.kv_block_struct(ctx, prompt_len=19, cache_len=32)
    a = kv.KVLayout.from_struct(struct_a)
    b = kv.KVLayout.from_struct(struct_b)
    assert a.total == b.total
    assert [leaf.shape for leaf in a.leaves] == [leaf.shape for leaf in b.leaves]


# --------------------------------------------------------------------------- #
# paged KV pool: layout, allocator, store, vectored page fetch
# --------------------------------------------------------------------------- #
def _smoke_model():
    from repro.configs.registry import SMOKE
    from repro.models.build import build_model
    from repro.parallel.ctx import RunCtx

    cfg = SMOKE["qwen3-4b"]
    model = build_model(cfg)
    ctx = RunCtx(mesh=None, remat="none")
    return cfg, model, ctx


def test_paged_layout_round_trips_model_cache():
    cfg, model, ctx = _smoke_model()
    params, _ = model.init(ctx, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    _, caches = model.prefill(params, ctx, {"inputs": toks}, cache_len=32)

    layout = pool.PagedLayout.from_struct(
        model.kv_block_struct(ctx, prompt_len=8, cache_len=32),
        cache_len=32,
        page_tokens=8,
    )
    assert layout.n_pages == 4
    pages = layout.flatten(caches)
    assert pages.shape == (4, layout.page_elems)
    restored = layout.unflatten(pages)
    for a, b in zip(jax.tree.leaves(caches), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # paged and dense flattenings carry the same payload volume
    dense = kv.KVLayout.from_struct(
        model.kv_block_struct(ctx, prompt_len=8, cache_len=32)
    )
    assert layout.n_pages * layout.page_elems == dense.total


def test_kv_page_struct_matches_layout():
    _, model, ctx = _smoke_model()
    page_struct, n_pages = model.kv_page_struct(ctx, cache_len=32, page_tokens=8)
    layout = pool.PagedLayout.from_struct(
        model.kv_block_struct(ctx, prompt_len=4, cache_len=32),
        cache_len=32,
        page_tokens=8,
    )
    assert n_pages == layout.n_pages
    per_page = sum(int(np.prod(leaf.shape)) for leaf in jax.tree.leaves(page_struct))
    assert per_page == layout.page_elems
    with pytest.raises(ValueError, match="not a multiple"):
        model.kv_page_struct(ctx, cache_len=32, page_tokens=5)


def test_pool_allocator_refcounts_and_cow():
    st = pool.make_pool(4)
    st, a = pool.alloc(st, 2)
    st = pool.fork(st, (a[0],))  # shared prefix page
    st = pool.free(st, a)  # a[0] still live (refcount 1), a[1] free
    pool.check_pool(st)
    assert st.n_free == 3
    st, fresh, copied = pool.writable(st, a[0])
    assert not copied and fresh == a[0]
    st = pool.fork(st, (a[0],))
    st, fresh, copied = pool.writable(st, a[0])
    assert copied and fresh != a[0]  # copy-on-write split
    pool.check_pool(st)
    st = pool.free(st, (a[0], fresh))
    pool.check_pool(st)
    assert st.n_free == 4
    with pytest.raises(pool.DoubleFreeError):
        pool.free(st, (a[0],))
    with pytest.raises(pool.OutOfPagesError):
        pool.alloc(st, 5)


def test_store_prefix_sharing_resolves_same_physical_pages():
    _, model, ctx = _smoke_model()
    layout = pool.PagedLayout.from_struct(
        model.kv_block_struct(ctx, prompt_len=4, cache_len=32),
        cache_len=32,
        page_tokens=8,
    )
    store = pool.PagedKVStore(layout, 12)
    rng = np.random.default_rng(0)
    pages = rng.normal(size=(layout.n_pages, layout.page_elems)).astype(np.float32)
    shared = list(range(100, 120))  # 2 full pages + a partial third
    p1 = store.admit(1, shared, pages)
    p2 = store.admit(2, shared + [7], pages)
    # two prefix-sharing requests map the SAME physical pages
    assert p1.table[:2] == p2.table[:2]
    assert not p2.fresh[0] and not p2.fresh[1]
    # the partial boundary page is private
    assert p1.table[2] != p2.table[2]
    assert store.prefix_match(shared) == 2
    store.release(1)
    assert store.prefix_match(shared) == 2  # rid 2 keeps the pages live
    store.release(2)
    assert store.prefix_match(shared) == 0  # last ref dropped the index
    assert store.n_free == 12
    pool.check_pool(store.state)


def test_fetch_pages_vectored_get_round_trip():
    """Each rank prefetches 3 pages from its neighbour's pool shard with
    the split-phase vectored get; the fetched carrier rows must equal the
    owner's pages (lockstep simulator, both batch counts)."""
    n, pages_per_rank, page_elems = 3, 4, 6
    rng = np.random.default_rng(1)
    shards = [
        jnp.asarray(rng.normal(size=(pages_per_rank * page_elems,)), jnp.float32)
        for _ in range(n)
    ]
    pmap = pool.PoolMap(n, pages_per_rank, page_elems)
    want_pages = (3, 0, 2)

    def make_program(n_batches):
        def program(engine):
            node = gasnet.Node(
                engine,
                am.HandlerTable(),
                am_capacity=4,
                am_payload_width=1,
                am_per_peer_capacity=4,
            )
            seg = shards[engine.rank][None]
            offsets = [pmap.offset(p) for p in want_pages]
            handles, plan = pool.fetch_pages(
                node,
                seg,
                jnp.stack(offsets),
                frm=gasnet.Shift(1),
                page_elems=page_elems,
                n_batches=n_batches,
            )
            assert plan.op == "p2p"
            return pool.sync_fetch(node, handles)

        return program

    for g in (1, 3):
        outs = run_spmd(make_program(g), n)
        for rank, got in enumerate(outs):
            owner = (rank + 1) % n
            want = np.asarray(shards[owner]).reshape(pages_per_rank, page_elems)[
                list(want_pages)
            ]
            np.testing.assert_array_equal(np.asarray(got), want)


def test_get_nbv_pred_gated(n=4):
    """Vectored get with pred=False completes to zeros (SPMD conditional
    fetch) while gated-true ranks receive the remote slices."""

    def program(engine):
        node = gasnet.Node(
            engine,
            am.HandlerTable(),
            am_capacity=4,
            am_payload_width=1,
            am_per_peer_capacity=4,
        )
        seg = (jnp.arange(8, dtype=jnp.float32) + 10 * engine.rank)[None]
        h = node.get_nbv(
            seg,
            frm=gasnet.Shift(1),
            indices=[0, 4],
            size=2,
            pred=engine.rank % 2 == 0,
        )
        return node.sync(h)

    outs = run_spmd(program, n)
    for rank, got in enumerate(outs):
        got = np.asarray(got)
        if rank % 2 == 0:
            src = (rank + 1) % n
            want = np.asarray([[0.0, 1.0], [4.0, 5.0]]) + 10 * src
            np.testing.assert_array_equal(got, want)
        else:
            np.testing.assert_array_equal(got, 0.0)


def test_paged_server_token_parity_and_pool_drain():
    """Colocated paged server: tokens identical to the dense server, two
    prefix-sharing requests resolve to shared physical pages, and every
    page is freed when its request retires."""
    from repro.launch.serve import PagedServer, Request, Server

    cfg, model, ctx = _smoke_model()
    params, _ = model.init(ctx, jax.random.PRNGKey(0))

    def burst():
        rng = np.random.default_rng(3)
        shared = rng.integers(0, cfg.vocab, size=16).tolist()
        reqs = [
            Request(rid=0, prompt=shared + [5], max_new=4),
            Request(rid=1, prompt=shared + [9, 11], max_new=4),
            Request(
                rid=2,
                prompt=rng.integers(0, cfg.vocab, size=7).tolist(),
                max_new=5,
            ),
        ]
        return reqs

    dense = Server(model, ctx, params, 2, 32)
    for r in burst():
        dense.submit(r)
    dense.run_until_drained()

    paged = PagedServer(model, ctx, params, 2, 32, page_tokens=8)
    for r in burst():
        paged.submit(r)
    stats = paged.run_until_drained()

    base = {r.rid: r.out for r in dense.finished}
    got = {r.rid: r.out for r in paged.finished}
    assert base.keys() == got.keys()
    for rid in base:
        assert base[rid] == got[rid], (rid, base[rid], got[rid])
    # rid 0/1 share 16 prompt tokens = 2 physical pages
    assert stats["pool_prefix_hits"] >= 2
    # allocator fully drained: no leaked pages
    assert stats["pool_n_free"] == stats["pool_n_pages"]
    pool.check_pool(paged.store.state)


def test_pooled_decode_server_runs_only_the_paged_path(monkeypatch):
    """The disagg decode server (:class:`PooledDecodeServer`) decodes
    through ``Model.decode_step_paged`` exclusively — dense
    ``decode_step`` is never called — and its tokens match the dense
    oracle exactly."""
    from repro.launch.serve import PooledDecodeServer, Request, Server

    cfg, model, ctx = _smoke_model()
    params, _ = model.init(ctx, jax.random.PRNGKey(0))
    cache_len, pt = 32, 8
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, int(n)).tolist()
               for n in (11, 7, 14)]
    max_new = [5, 6, 4]

    # dense oracle first, with the unpatched model
    dense = Server(model, ctx, params, 2, cache_len)
    for rid, (p, m) in enumerate(zip(prompts, max_new)):
        dense.submit(Request(rid=rid, prompt=p, max_new=m))
    dense.run_until_drained()
    want = {r.rid: r.out for r in dense.finished}

    calls = {"paged": 0, "dense": 0}
    orig_paged, orig_dense = model.decode_step_paged, model.decode_step

    def spy_paged(*a, **k):
        calls["paged"] += 1
        return orig_paged(*a, **k)

    def spy_dense(*a, **k):
        calls["dense"] += 1
        return orig_dense(*a, **k)

    monkeypatch.setattr(model, "decode_step_paged", spy_paged)
    monkeypatch.setattr(model, "decode_step", spy_dense)

    layout = pool.PagedLayout.from_struct(
        model.kv_block_struct(ctx, prompt_len=8, cache_len=cache_len),
        cache_len=cache_len, page_tokens=pt,
    )
    store = pool.PagedKVStore(layout, n_pages=16)
    server = PooledDecodeServer(
        model, ctx, params, 2, cache_len, store=store
    )
    # play the cluster: prefill each prompt, put its pages into the pool
    # shard, bind the decode row by rid (no dense cache row anywhere)
    pending = []
    for rid, (p, m) in enumerate(zip(prompts, max_new)):
        toks = jnp.asarray(p, jnp.int32)[None]
        logits, caches = model.prefill(
            params, ctx, {"inputs": toks}, cache_len=cache_len
        )
        t0 = int(np.argmax(np.asarray(logits)[0]))
        pages = np.asarray(layout.flatten(caches))
        pending.append(
            (Request(rid=rid, prompt=p, max_new=m), t0, len(p), pages)
        )
    pending_later = None
    for req, t0, position, pages in pending:
        if server.admit_paged(req, t0, position):
            store.admit(req.rid, req.prompt, pages)
        else:
            pending_later = (req, t0, position, pages)
    for _ in range(200):
        if server.step() == 0:
            # a decode row freed up: bind the queued third request
            if pending_later is not None:
                req, t0, position, pages = pending_later
                assert server.admit_paged(req, t0, position)
                store.admit(req.rid, req.prompt, pages)
                pending_later = None
                continue
            break
    got = {r.rid: r.out for r in server.finished}
    assert got == want
    assert calls["paged"] >= 1        # decode went through the paged path
    assert calls["dense"] == 0        # dense decode is only the oracle
    assert server.paged_decode_steps >= max(max_new)


# --------------------------------------------------------------------------- #
# tiered KV memory: vectored put, swap round trip, lazy pool, scheduler
# --------------------------------------------------------------------------- #
def test_put_nbv_vectored_put_round_trip(n=4):
    """m payloads + their target offsets + per-page flags in one command
    block: flagged payloads land at their offsets of the neighbour's
    partition, cleared ones leave the receiver untouched."""

    def program(engine):
        node = gasnet.Node(
            engine, am.HandlerTable(), am_capacity=4,
            am_payload_width=1, am_per_peer_capacity=4,
        )
        seg = jnp.zeros((1, 16), jnp.float32)
        datas = jnp.stack(
            [jnp.full((3,), 10.0 * engine.rank + j) for j in range(2)]
        )
        h = node.put_nbv(
            seg, datas, to=gasnet.Shift(1), indices=[2, 9],
            pred=[True, engine.rank % 2 == 0],
        )
        return node.sync(h)

    outs = run_spmd(program, n)
    for rank, seg in enumerate(outs):
        got = np.asarray(seg)[0]
        src = (rank - 1) % n
        np.testing.assert_array_equal(got[2:5], 10.0 * src)
        if src % 2 == 0:
            np.testing.assert_array_equal(got[9:12], 10.0 * src + 1)
        else:
            np.testing.assert_array_equal(got[9:12], 0.0)
        np.testing.assert_array_equal(got[:2], 0.0)


def test_swap_out_swap_in_round_trip(n=3):
    """Pool pages swap OUT to a memory rank's segment (vectored put) and
    back IN (vectored get + install) bit-exactly — NaN payloads included
    (int bit patterns riding the float32 carrier)."""
    from repro.serving import tier

    page_elems, n_pages = 5, 4
    rng = np.random.default_rng(0)
    bits = rng.integers(
        -(2**31), 2**31 - 1, size=(n_pages, page_elems), dtype=np.int64
    ).astype(np.int32)
    pages = jnp.asarray(bits.view(np.float32))
    src_pages, dst_slots = (3, 1), (0, 2)
    src_offs = [p * page_elems for p in src_pages]
    dst_offs = [s * page_elems for s in dst_slots]

    def prog_out(engine):
        node = gasnet.Node(
            engine, am.HandlerTable(), am_capacity=4,
            am_payload_width=1, am_per_peer_capacity=4,
        )
        # rank 0 = decode shard holding the pages; rank 1 = memory rank
        seg = jnp.where(engine.rank == 0, pages.reshape(-1),
                        jnp.zeros((n_pages * page_elems,)))[None]
        handles, plan = tier.swap_out_pages(
            node, seg, src_offs, dst_offs,
            to=gasnet.Perm(kv.handoff_permutation(n, {0: 1})),
            page_elems=page_elems,
            flags=[1, 1] if engine.rank == 0 else [0, 0],
        )
        assert plan.op == "p2p"
        for h in handles:
            seg = node.sync(h)
        return seg

    outs = run_spmd(prog_out, n)
    mem_rank = np.asarray(outs[1])[0].reshape(n_pages, page_elems)
    for sp, ds in zip(src_pages, dst_slots):
        assert mem_rank[ds].tobytes() == bits[sp].view(np.float32).tobytes()
    # untouched slots stay zero, and the non-flagged ranks shipped nothing
    assert np.asarray(outs[2])[0].tobytes() == b"\x00" * (4 * n_pages * page_elems)

    # swap-in: fetch the tier slots back and install at fresh pool offsets
    tier_seg = jnp.asarray(mem_rank.reshape(-1))
    new_offs = [0 * page_elems, 2 * page_elems]

    def prog_in(engine):
        node = gasnet.Node(
            engine, am.HandlerTable(), am_capacity=4,
            am_payload_width=1, am_per_peer_capacity=4,
        )
        seg = jnp.where(engine.rank == 1, tier_seg,
                        jnp.zeros_like(tier_seg))[None]
        h = node.get_nbv(
            seg, frm=gasnet.Perm(kv.handoff_permutation(n, {0: 1})),
            indices=jnp.asarray(dst_offs), size=page_elems,
            pred=engine.rank == 0,
        )
        fetched = node.sync(h)
        flags = [1, 1] if engine.rank == 0 else [0, 0]
        return tier.install_pages(node, seg, fetched, new_offs, flags)

    outs = run_spmd(prog_in, n)
    restored = np.asarray(outs[0])[0].reshape(n_pages, page_elems)
    for sp, (np_, _) in zip(src_pages, [(0, 0), (2, 2)]):
        assert restored[np_].tobytes() == bits[sp].view(np.float32).tobytes()


def test_memory_tier_bookkeeping():
    from repro.serving import tier

    t = tier.MemoryTier(2, 3, 4, host_backed=True)
    h = t.plan_swap_out(7, [0, 2, 1])
    assert h.logical == (0, 1, 2) and len(h.slots) == 3
    rows = np.arange(12, dtype=np.float32).reshape(3, 4)
    t.host_store(7, rows)
    np.testing.assert_array_equal(t.host_load(7), rows)
    tier.check_tier(t)
    with pytest.raises(tier.TierError):
        t.plan_swap_out(7, [0])  # already resident
    h2 = t.plan_swap_out(8, [1, 3])
    assert h2.rank != h.rank  # most-free rank balancing
    with pytest.raises(tier.OutOfSlotsError):
        t.plan_swap_out(9, [0, 1, 2, 3])  # no rank has 4 free slots
    tier.check_tier(t, resident_rids=[1, 2])
    with pytest.raises(AssertionError, match="pool AND tier"):
        tier.check_tier(t, resident_rids=[7])
    t.release(7)
    t.release(8)
    tier.check_tier(t)
    assert t.n_free == 6
    with pytest.raises(tier.TierError):
        t.release(7)


def test_lazy_admit_gather_synthesis_and_extended_invariant():
    """Lazy admission materialises only prompt pages; gather synthesises
    the absent tail from the cache-init bytes (pos=-1, payload 0) even
    after the physical pages were recycled with stale contents; the
    extended check_pool covers unmaterialised slots and evicted tables."""
    struct = {
        "k": jax.ShapeDtypeStruct((2, 1, 12, 3), jnp.float32),
        "pos": jax.ShapeDtypeStruct((2, 1, 12), jnp.int32),
    }
    layout = pool.PagedLayout.from_struct(struct, cache_len=12, page_tokens=4)
    store = pool.PagedKVStore(layout, 4)
    plan = store.plan_admit([1, 2, 3, 4, 5], lazy=True)  # 5 tokens -> 2 pages
    assert plan.table[2] == pool.UNMATERIALIZED
    assert plan.n_materialized == 2
    store.commit(1, plan)
    # poison the whole pool memory: recycled stale bytes everywhere
    store.mem[:] = np.nan
    caches = store.gather(1)
    kp = np.asarray(caches["pos"])
    assert (kp[:, :, 8:] == -1).all()  # absent page: init bytes, not stale
    assert not np.isnan(np.asarray(caches["k"])[:, :, 8:]).any()
    pool.check_pool(store.state, tables=store.tables.values())
    # materialise the tail by writing position 8 (page 2)
    phys = store.prepare_write(1, 8)
    assert store.tables[1][2] == phys
    # bitwise: the pos=-1 init bitcasts to NaN in the float32 carrier
    assert store.mem[phys].tobytes() == layout.empty_page_row().tobytes()
    pool.check_pool(store.state, tables=store.tables.values())
    # evict: references drop, snapshot keeps the pairs
    pairs = store.evict_request(1)
    assert [lp for lp, _ in pairs] == [0, 1, 2]
    pool.check_pool(
        store.state, tables=[], evicted=[[pp for _, pp in pairs]]
    )
    assert store.n_free == 4
    # resume: fresh pages for the same logical set, rest unmaterialised
    phys2 = store.admit_resume(1, [lp for lp, _ in pairs])
    assert len(phys2) == 3 and store.tables[1].count(pool.UNMATERIALIZED) == 0
    pool.check_pool(store.state, tables=store.tables.values())
    store.release(1)
    assert store.n_free == 4
    # materialize_through is transactional: a mid-loop OutOfPagesError
    # must roll back the pages it already took (no silent pool shrink)
    p1 = store.plan_admit([1], lazy=True)  # 1 page + 2 unmaterialised
    store.commit(1, p1)
    p2 = store.plan_admit([9, 9, 9, 9, 9], lazy=True)
    store.commit(2, p2)  # 2 more pages: 1 page left free
    with pytest.raises(pool.OutOfPagesError):
        store.materialize_through(1, 3)  # needs 2, only 1 free
    pool.check_pool(store.state, tables=store.tables.values())
    assert store.n_free == 1  # nothing leaked by the failed attempt
    assert store.tables[1].count(pool.UNMATERIALIZED) == 2
    store.release(1)
    store.release(2)
    assert store.n_free == 4


def test_scheduler_order_victims_and_cost_model():
    from repro.core.sched import EngineCost
    from repro.serving.scheduler import SLO, AdmissionScheduler, swap_or_recompute

    s = AdmissionScheduler(page_bytes=1024)
    s.submit(1, SLO(priority=0, ttft_deadline_s=5.0), now=0.0)
    s.submit(2, SLO(priority=1), now=1.0)
    s.submit(3, SLO(priority=0, ttft_deadline_s=1.0), now=0.0)
    # priority-major, then EDF within a priority
    assert s.admission_order() == [2, 3, 1]
    s.on_admitted(2)
    s.on_preempted(2, "swap")
    s.submit(4, SLO(priority=1), now=2.0)
    # resume-first within a priority: the victim outranks the new arrival
    assert s.admission_order()[:2] == [2, 4]
    for rid in (2, 4):
        s.on_admitted(rid)
    # victims: lowest priority first, never above the beneficiary; strict
    # excludes equal priority
    s.on_admitted(1)
    free = {1: 3, 2: 2, 4: 2}
    assert s.pick_victims([1, 2, 4], 3, free.get, beneficiary=2) == [1]
    assert s.pick_victims([1, 4], 2, free.get, beneficiary=3, strict=False) == [1]
    assert s.pick_victims([4], 2, free.get, beneficiary=3) == []
    assert s.pick_victims([1], 9, free.get, beneficiary=2) == []  # not enough
    # beta model: many pages + few generated tokens -> swap; the reverse
    # -> recompute
    cost = EngineCost(alpha_us=10.0, beta_us_per_kib=1.0, gamma_us_per_kib=0.0)
    mode, _, _ = swap_or_recompute(4, 1024, 100, cost,
                                   decode_step_us=100.0, prefill_us=100.0)
    assert mode == "swap"
    mode, _, _ = swap_or_recompute(64, 1 << 20, 1, cost,
                                   decode_step_us=100.0, prefill_us=100.0)
    assert mode == "recompute"


def test_paged_decode_step_matches_dense_decode():
    """The end-to-end paged decode (page-table scatter + paged attention)
    derives the same tokens as the dense cache path, page pool shuffled."""
    cfg, model, ctx = _smoke_model()
    params, _ = model.init(ctx, jax.random.PRNGKey(0))
    cache_len, pt = 32, 8
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, 11).tolist()
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, caches = model.prefill(params, ctx, {"inputs": toks}, cache_len=cache_len)
    t0 = int(np.argmax(np.asarray(logits)[0]))
    layout = pool.PagedLayout.from_struct(
        model.kv_block_struct(ctx, prompt_len=len(prompt), cache_len=cache_len),
        cache_len=cache_len, page_tokens=pt,
    )
    pages = np.asarray(layout.flatten(caches))
    order = [2, 0, 3, 1]  # scattered physical placement
    mem = np.zeros((5, layout.page_elems), np.float32)
    for lp, ph in enumerate(order):
        mem[ph] = pages[lp]
    table = jnp.asarray([order], jnp.int32)

    dense, paged = [t0], [t0]
    pos, last, dc = len(prompt), t0, caches
    for _ in range(5):
        lg, dc = model.decode_step(
            params, ctx, jnp.asarray([[last]], jnp.int32),
            jnp.asarray([pos], jnp.int32), dc,
        )
        last = int(np.argmax(np.asarray(lg)[0]))
        dense.append(last)
        pos += 1
    views = layout.decode_views(jnp.asarray(mem))
    pos, last = len(prompt), t0
    for _ in range(5):
        lg, views = model.decode_step_paged(
            params, ctx, jnp.asarray([[last]], jnp.int32),
            jnp.asarray([pos], jnp.int32), views, table,
        )
        last = int(np.argmax(np.asarray(lg)[0]))
        paged.append(last)
        pos += 1
    assert dense == paged
    # views <-> carrier pool round trip is bit-exact
    back = np.asarray(layout.views_to_pool(layout.decode_views(jnp.asarray(mem))))
    assert back.tobytes() == mem.tobytes()


def test_oversubscribed_paged_server_preempts_bit_identically():
    """Aggregate KV demand ~1.7x the pool: the scheduler preempts, pages
    swap to the (host-backed) memory tier, every request resumes and the
    token streams match the unpressured dense run exactly; pool and tier
    fully drain.  A recompute-priced run replays instead of swapping and
    must match too."""
    from repro.launch.serve import PagedServer, Request, Server

    cfg, model, ctx = _smoke_model()
    params, _ = model.init(ctx, jax.random.PRNGKey(0))

    def burst():
        rng = np.random.default_rng(3)
        return [
            Request(
                rid=r,
                prompt=rng.integers(0, cfg.vocab,
                                    size=int(rng.integers(6, 18))).tolist(),
                max_new=int(rng.integers(6, 12)),
            )
            for r in range(6)
        ]

    dense = Server(model, ctx, params, 3, 32)
    for r in burst():
        dense.submit(r)
    dense.run_until_drained()
    base = {r.rid: r.out for r in dense.finished}

    for kwargs, expect in (
        ({}, "sched_swaps"),
        ({"decode_step_us": 1e-3, "prefill_us": 1e-3}, "sched_recomputes"),
    ):
        srv = PagedServer(model, ctx, params, 3, 32, page_tokens=8,
                          n_pool_pages=7, **kwargs)
        for r in burst():
            srv.submit(r)
        stats = srv.run_until_drained(max_ticks=500)
        got = {r.rid: r.out for r in srv.finished}
        assert base.keys() == got.keys()
        for rid in base:
            assert base[rid] == got[rid], (rid, base[rid], got[rid])
        assert stats["sched_evictions"] >= 1
        assert stats[expect] >= 1
        assert stats["pool_n_free"] == stats["pool_n_pages"]
        assert stats["tier_free_slots"] == stats["tier_slots"]
        pool.check_pool(
            srv.store.state, tables=list(srv.store.tables.values())
        )


def test_why_slow_blames_eviction_for_preempted_requests():
    """Act-3 shape (aggregate KV demand ~1.4x the pool, five requests
    over three slots) with the tracer on: the low-priority victim is
    swapped out and cannot resume while higher-priority work convoys
    through the slots — critical-path attribution must blame the
    eviction (dominant segment swap/replay), and ``why_slow`` names it
    plus the co-resident convoy."""
    from repro.launch.serve import PagedServer, Request
    from repro.obs import attrib
    from repro.obs import trace as obs_trace
    from repro.serving.scheduler import SLO

    cfg, model, ctx = _smoke_model()
    params, _ = model.init(ctx, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    srv = PagedServer(model, ctx, params, 3, 32, page_tokens=8,
                      n_pool_pages=14)
    # warm the jitted prefill/decode shapes so the traced run's walls
    # measure scheduling, not one-off compilation
    srv.submit(Request(rid=99,
                       prompt=rng.integers(0, cfg.vocab, 8).tolist(),
                       max_new=20))
    srv.run_until_drained(max_ticks=100)

    def mk(rid, max_new, prio):
        return Request(
            rid=rid, prompt=rng.integers(0, cfg.vocab, 8).tolist(),
            max_new=max_new, slo=SLO(priority=prio),
        )

    tr = obs_trace.enable(capacity=1 << 15)
    try:
        srv.submit(mk(0, 6, 0))  # the victim: short, low priority
        srv.submit(mk(1, 20, 1))
        srv.submit(mk(2, 20, 1))
        for _ in range(3):
            srv.step()
        srv.submit(mk(3, 20, 1))
        srv.submit(mk(4, 20, 1))
        srv._preempt(0, "swap")  # the pressure point: pool + slots full
        stats = srv.run_until_drained(max_ticks=500)
    finally:
        obs_trace.disable()
    assert stats["sched_swaps"] >= 1
    downs = attrib.attribute(tr)
    assert {0, 1, 2, 3, 4} <= set(downs)
    bd = downs[0]
    assert bd.state == "retired" and bd.n_preempts == 1
    # the eviction window — not decode, not queueing — is the victim's
    # critical path: it sat swapped out while p1 work held the slots
    assert bd.dominant() == "swap", bd.segments
    assert bd.segments["swap"] > bd.segments["decode"]
    report = attrib.why_slow(tr, 0)
    assert "dominant: swap" in report
    # the pool was full while it waited: the p1 convoy is named
    assert "convoyed by" in report and "rid 1" in report


def test_paged_server_health_backpressure_defers_low_priority():
    """A tight-TTFT high-priority request at risk raises the admission
    floor: the paged server stops admitting below-floor work until the
    at-risk set drains (counted on ``sched_deferrals``), and every
    request still completes."""
    from repro.launch.serve import PagedServer, Request
    from repro.obs.health import HealthMonitor
    from repro.serving.scheduler import SLO

    cfg, model, ctx = _smoke_model()
    params, _ = model.init(ctx, jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    mon = HealthMonitor()
    srv = PagedServer(model, ctx, params, 2, 32, page_tokens=8,
                      health=mon)
    assert srv.scheduler.health is mon
    # an (unmeetably) tight TPOT deadline keeps rid 0 at risk for its
    # whole residence — the floor stays at p2 until it retires
    srv.submit(Request(
        rid=0, prompt=rng.integers(0, cfg.vocab, 8).tolist(), max_new=4,
        slo=SLO(priority=2, tpot_deadline_s=1e-9),
    ))
    srv.step()  # admit rid 0; the post-step health tick raises the floor
    assert mon.backpressure_floor() == 2
    srv.submit(Request(
        rid=1, prompt=rng.integers(0, cfg.vocab, 8).tolist(), max_new=4,
        slo=SLO(priority=0),
    ))
    srv.step()  # rid 1 is below the floor: deferred, not admitted
    assert srv.scheduler.deferrals >= 1
    assert all(r is None or r.rid == 0 for r in srv.active)
    stats = srv.run_until_drained(max_ticks=300)
    assert stats["requests"] == 2  # backpressure defers, never starves
    assert stats["sched_deferrals"] >= 1
    assert mon.last_summary["tracked"] == 0  # retirement untracks
    assert mon.registry.counter("slo_violations").get() >= 1
    """A request recompute-preempted, resumed, then swap-preempted WHILE
    still replaying must carry its replay tail across the swap — no
    re-appended tokens, bit-identical output."""
    from repro.launch.serve import PagedServer, Request, Server

    cfg, model, ctx = _smoke_model()
    params, _ = model.init(ctx, jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab, 9).tolist()

    dense = Server(model, ctx, params, 2, 32)
    dense.submit(Request(rid=0, prompt=list(prompt), max_new=10))
    dense.run_until_drained()
    want = dense.finished[0].out

    srv = PagedServer(model, ctx, params, 2, 32, page_tokens=8)
    req = Request(rid=0, prompt=list(prompt), max_new=10)
    srv.submit(req)
    for _ in range(5):
        srv.step()
    srv._preempt(0, "recompute")
    srv.step()  # resume: re-prefill + arm replay
    assert srv.replaying, "expected the resumed row to be replaying"
    srv._preempt(0, "swap")  # swap OUT mid-replay
    assert srv._preempted[0]["replay"], "replay tail must ride the snapshot"
    stats = srv.run_until_drained(max_ticks=200)
    assert [r.out for r in srv.finished] == [want]
    assert stats["pool_n_free"] == stats["pool_n_pages"]
    assert stats["tier_free_slots"] == stats["tier_slots"]


def test_dense_paged_server_pool_stays_canonical():
    """paged_decode=False (the PR-4 row path): every decode step writes
    its dirty page back, so a gather through the page table always
    returns the row's current bytes."""
    from repro.launch.serve import PagedServer, Request

    cfg, model, ctx = _smoke_model()
    params, _ = model.init(ctx, jax.random.PRNGKey(0))
    rng = np.random.default_rng(6)
    srv = PagedServer(model, ctx, params, 2, 32, page_tokens=8,
                      paged_decode=False)
    srv.submit(Request(rid=0, prompt=rng.integers(0, cfg.vocab, 9).tolist(),
                       max_new=6))
    for _ in range(4):
        srv.step()
    row = srv.jax.tree.map(lambda x: x[:, 0:1], srv.caches)
    gathered = srv.store.gather(0)
    for a, b in zip(jax.tree.leaves(row), jax.tree.leaves(gathered)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


# --------------------------------------------------------------------------- #
# end-to-end: the example's prefill -> KV put -> decode round trip
# --------------------------------------------------------------------------- #
@pytest.mark.slow
def test_disagg_serve_example_smoke():
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(ROOT, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    cmd = [
        sys.executable,
        os.path.join(ROOT, "examples", "serve_requests.py"),
        "--smoke",
    ]
    proc = subprocess.run(
        cmd, capture_output=True, text=True, timeout=1200, env=env, cwd=ROOT
    )
    assert proc.returncode == 0, proc.stdout[-4000:] + proc.stderr[-4000:]
    # KV transfer planned by plan_p2p...
    assert "kv plan: p2p[" in proc.stdout
    # ...acknowledged via an AM reply...
    assert "acked via AM reply: 6" in proc.stdout
    # ...across distinct prefill/decode ranks, token-identical to the
    # colocated baseline
    assert "parity: disaggregated tokens == colocated tokens" in proc.stdout
    # ...and the paged act: pages land in the pool, the prefix-sharing
    # pair maps shared physical pages, tokens stay identical
    assert "prefix-shared pages mapped not moved" in proc.stdout
    assert "parity: paged tokens == dense tokens" in proc.stdout
    # ...and the tiered act: an oversubscribed pool preempts, pages swap
    # to the memory-only rank over the vectored put, resumes are
    # bit-identical and both tiers drain
    assert "tiered KV memory: 1 memory rank(s)" in proc.stdout
    assert "bit-identical resume after swap to the memory rank" in proc.stdout
    assert "pool + memory tier fully drained at shutdown" in proc.stdout
    assert "DISAGG_SERVE_PASS" in proc.stdout

"""Disaggregated serving: AM request/reply plane + KV-block data plane.

Fast tests run the GAS programs on the single-device lockstep simulator
(``repro.testing.sim``) and validate the KV block layout against real
model caches; the slow test runs the end-to-end example (distinct
prefill/decode ranks, plan_p2p-segmented puts, AM-reply acks) in a
subprocess with forced host devices.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import am, gasnet
from repro.serving import kv
from repro.testing.sim import run_spmd

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --------------------------------------------------------------------------- #
# AM request/reply round trip (lockstep simulator, single device)
# --------------------------------------------------------------------------- #
def _pingpong_table():
    table = am.HandlerTable()

    def pong(state, payload, args):
        out = dict(state)
        out["ack_payload"] = payload
        out["ack_arg"] = state["ack_arg"] + args[0]
        return out

    pong_id = table.register("pong", pong)

    def ping(state, payload, args):
        out = dict(state)
        out["got"] = state["got"] + args[0]
        reply = am.reply_medium(pong_id, payload + 1.0, args=(args[0] + 1,))
        return out, reply

    table.register("ping", ping, replies=True)
    return table


@pytest.mark.parametrize("n,shift", [(2, 1), (5, 3), (8, 5)])
def test_am_request_reply_round_trip(n, shift):
    def program(engine):
        node = gasnet.Node(
            engine,
            _pingpong_table(),
            am_capacity=8,
            am_payload_width=4,
            am_per_peer_capacity=8,
        )
        me = node.my_id
        state = {
            "got": jnp.zeros((), jnp.int32),
            "ack_arg": jnp.zeros((), jnp.int32),
            "ack_payload": jnp.zeros((4,), jnp.float32),
        }
        handle = node.am_call(
            (me + shift) % n,
            "ping",
            payload=jnp.full((4,), me, jnp.float32),
            args=(me * 10,),
            ack=lambda st: st["ack_payload"],
        )
        state = node.am_flush(state)
        return state["got"], state["ack_arg"], node.sync(handle)

    outs = run_spmd(program, n)
    for rank, (got, ack_arg, ack_payload) in enumerate(outs):
        # request hop: handler ran at rank (me + shift) % n
        assert int(got) == ((rank - shift) % n) * 10
        # reply hop: the AMReply came back to the requester
        assert int(ack_arg) == rank * 10 + 1
        np.testing.assert_allclose(np.asarray(ack_payload), rank + 1.0)


def test_am_call_requires_replying_handler():
    table = am.HandlerTable()
    table.register("plain", lambda s, p, a: s)

    def program(engine):
        node = gasnet.Node(
            engine, table, am_capacity=4, am_payload_width=2, am_per_peer_capacity=4
        )
        with pytest.raises(ValueError, match="replying"):
            node.am_call(jnp.zeros((), jnp.int32), "plain")
        return jnp.zeros(())

    run_spmd(program, 2)


def test_ack_handle_sync_before_flush_raises():
    table = _pingpong_table()

    def program(engine):
        node = gasnet.Node(
            engine, table, am_capacity=4, am_payload_width=4, am_per_peer_capacity=4
        )
        handle = node.am_call(
            jnp.zeros((), jnp.int32),
            "ping",
            payload=jnp.zeros((4,), jnp.float32),
            ack=lambda st: st["ack_arg"],
        )
        with pytest.raises(RuntimeError, match="before am_flush"):
            node.sync(handle)
        return jnp.zeros(())

    run_spmd(program, 2)


# --------------------------------------------------------------------------- #
# KV-block data plane (simulator)
# --------------------------------------------------------------------------- #
def _kv_push_ranks(n, block, n_segments, n_slots=2, slot=1, gate=None):
    """Every rank pushes its block to rank (me+1) % n, segmented."""
    rng = np.random.default_rng(block + n)
    blocks = [jnp.asarray(rng.normal(size=(block,)), jnp.float32) for _ in range(n)]

    def program(engine):
        node = gasnet.Node(
            engine,
            am.HandlerTable(),
            am_capacity=4,
            am_payload_width=1,
            am_per_peer_capacity=4,
        )
        seg = jnp.zeros((1, n_slots * block), jnp.float32)
        pred = None if gate is None else gate[engine.rank]
        handles, plan = kv.push_block(
            node,
            seg,
            blocks[engine.rank],
            to=gasnet.Shift(1),
            base_index=slot * block,
            pred=pred,
            n_segments=n_segments,
        )
        assert plan.op == "p2p"
        seg = kv.sync_push(node, seg, handles)
        return seg

    return blocks, run_spmd(program, n)


@pytest.mark.parametrize("n,block,g", [(2, 7, 1), (3, 16, 4), (4, 33, 5)])
def test_segmented_kv_push_lands_whole_block(n, block, g):
    blocks, segs = _kv_push_ranks(n, block, g)
    for rank, seg in enumerate(segs):
        got = np.asarray(seg)[0]
        np.testing.assert_array_equal(got[block:], np.asarray(blocks[(rank - 1) % n]))
        np.testing.assert_array_equal(got[:block], 0.0)


def test_segmented_matches_monolithic_push():
    _, mono = _kv_push_ranks(3, 24, 1)
    _, seg = _kv_push_ranks(3, 24, 6)
    for a, b in zip(mono, seg):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pred_gated_push_leaves_receiver_untouched():
    n = 4
    gate = [r % 2 == 0 for r in range(n)]  # only even ranks send
    blocks, segs = _kv_push_ranks(n, 8, 3, gate=gate)
    for rank, seg in enumerate(segs):
        got = np.asarray(seg)[0, 8:]
        sender = (rank - 1) % n
        if gate[sender]:
            np.testing.assert_array_equal(got, np.asarray(blocks[sender]))
        else:
            np.testing.assert_array_equal(got, 0.0)


def test_handoff_permutation_completes_bijection():
    perm = kv.handoff_permutation(6, {0: 4, 1: 3})
    assert sorted(perm) == list(range(6))
    assert perm[0] == 4 and perm[1] == 3
    with pytest.raises(ValueError, match="duplicate destination"):
        kv.handoff_permutation(4, {0: 2, 1: 2})


def test_segment_bounds_cover_exactly():
    for total, g in [(1, 1), (7, 3), (12, 12), (10, 64)]:
        bounds = kv.segment_bounds(total, g)
        assert bounds[0][0] == 0
        assert sum(size for _, size in bounds) == total
        for (off_a, size_a), (off_b, _) in zip(bounds, bounds[1:]):
            assert off_a + size_a == off_b
        assert all(size > 0 for _, size in bounds)


# --------------------------------------------------------------------------- #
# KV layout: bit-exact round trip of real model caches
# --------------------------------------------------------------------------- #
def test_kv_layout_round_trips_model_cache():
    from repro.configs.registry import SMOKE
    from repro.models.build import build_model
    from repro.parallel.ctx import RunCtx

    cfg = SMOKE["qwen3-4b"]
    model = build_model(cfg)
    ctx = RunCtx(mesh=None, remat="none")
    params, _ = model.init(ctx, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    _, caches = model.prefill(params, ctx, {"inputs": toks}, cache_len=32)

    layout = kv.KVLayout.from_struct(
        model.kv_block_struct(ctx, prompt_len=8, cache_len=32)
    )
    flat = layout.flatten(caches)
    assert flat.shape == (layout.total,) and flat.dtype == jnp.float32
    restored = layout.unflatten(flat)

    ref_leaves = jax.tree_util.tree_leaves(caches)
    got_leaves = jax.tree_util.tree_leaves(restored)
    assert len(ref_leaves) == len(got_leaves)
    for a, b in zip(ref_leaves, got_leaves):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_kv_layout_shapes_independent_of_prompt_len():
    from repro.configs.registry import SMOKE
    from repro.models.build import build_model
    from repro.parallel.ctx import RunCtx

    cfg = SMOKE["qwen3-4b"]
    model = build_model(cfg)
    ctx = RunCtx(mesh=None, remat="none")
    struct_a = model.kv_block_struct(ctx, prompt_len=4, cache_len=32)
    struct_b = model.kv_block_struct(ctx, prompt_len=19, cache_len=32)
    a = kv.KVLayout.from_struct(struct_a)
    b = kv.KVLayout.from_struct(struct_b)
    assert a.total == b.total
    assert [leaf.shape for leaf in a.leaves] == [leaf.shape for leaf in b.leaves]


# --------------------------------------------------------------------------- #
# end-to-end: the example's prefill -> KV put -> decode round trip
# --------------------------------------------------------------------------- #
@pytest.mark.slow
def test_disagg_serve_example_smoke():
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(ROOT, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    cmd = [
        sys.executable,
        os.path.join(ROOT, "examples", "serve_requests.py"),
        "--smoke",
    ]
    proc = subprocess.run(
        cmd, capture_output=True, text=True, timeout=1200, env=env, cwd=ROOT
    )
    assert proc.returncode == 0, proc.stdout[-4000:] + proc.stderr[-4000:]
    # KV transfer planned by plan_p2p...
    assert "kv plan: p2p[" in proc.stdout
    # ...acknowledged via an AM reply...
    assert "acked via AM reply: 6" in proc.stdout
    # ...across distinct prefill/decode ranks, token-identical to the
    # colocated baseline
    assert "parity: disaggregated tokens == colocated tokens" in proc.stdout
    assert "DISAGG_SERVE_PASS" in proc.stdout

"""Fault-tolerant serving: replicated tier placements, elastic
membership, and the chaos suite.

Fast tests exercise the host-side bookkeeping directly — fanned
swap-out legs and quorum restores on the :class:`MemoryTier`, rank
failure scrubbing and re-admission, spare promotion in the role map,
prefix-index migration between pool shards, and the tick-clocked
:class:`HeartbeatMonitor`.  The slow test runs the deterministic
fault-injection suite (``repro.testing.fault_suite``) in a subprocess
with forced host devices: a decode rank dies mid-KV-handoff, a memory
rank dies holding swap legs, a spare joins mid-flight — every scenario
must finish with bit-exact tokens and clean pool/tier invariants on the
survivors.
"""

import numpy as np
import pytest

from repro.launch import mesh
from repro.runtime.ft import HeartbeatMonitor
from repro.serving import pool, tier


# --------------------------------------------------------------------------- #
# replicated memory tier: fanned legs, quorum restores, failure scrubbing
# --------------------------------------------------------------------------- #
def test_replicated_swap_out_fans_legs_and_quorum_restores():
    t = tier.MemoryTier(3, 4, 2, host_backed=True, replicas=2)
    h = t.plan_swap_out(1, [1, 0])
    # two legs on two DISTINCT ranks, primary first
    assert len(h.placements) == 2
    assert h.placements[0].rank != h.placements[1].rank
    assert t.replica_pages == 2
    rows = np.arange(4, dtype=np.float32).reshape(2, 2)
    t.host_store(1, rows)
    # the fanned store fed EVERY leg
    for pl in h.placements:
        got = np.stack([t.host_mem[pl.rank, s] for s in pl.slots])
        np.testing.assert_array_equal(got, rows)
    tier.check_tier(t)
    # primary alive: restore reads it, no quorum event
    assert t.restore_placement(1).rank == h.rank
    assert t.quorum_restores == 0
    # primary dies: nothing is lost, restore falls over to the replica
    assert t.mark_failed(h.rank) == []
    pl = t.restore_placement(1)
    assert pl.rank == h.placements[1].rank
    assert t.quorum_restores == 1
    np.testing.assert_array_equal(t.host_load(1), rows)
    tier.check_tier(t)
    # release returns only the LIVE leg's slots; the dead rank stays empty
    t.release(1)
    tier.check_tier(t)
    assert t.free_slots(h.rank) == 0
    assert t.n_free == 2 * 4  # the two surviving ranks


def test_tier_mark_failed_lost_rids_degradation_and_readmit():
    t = tier.MemoryTier(2, 4, 2, replicas=2)
    # one unreplicated holding on the tier: its rank's death loses it
    h = t.plan_swap_out(5, [0], replicas=1)
    assert len(h.placements) == 1
    lost = t.mark_failed(h.rank)
    assert lost == [5]
    assert 5 not in t.holdings
    with pytest.raises(tier.TierError):
        t.restore_placement(5)
    assert t.mark_failed(h.rank) == []  # idempotent
    tier.check_tier(t)
    # replicas=2 with one live rank: want clamps to the live count
    h2 = t.plan_swap_out(6, [0, 1], replicas=2)
    assert len(h2.placements) == 1
    t.release(6)
    # the dead rank rejoins empty and takes placements again
    t.admit_rank(h.rank)
    with pytest.raises(tier.TierError):
        t.admit_rank(h.rank)  # only failed ranks re-admit
    assert t.free_slots(h.rank) == 4
    # degradation: both ranks live, but only one can fit the leg
    t.plan_swap_out(7, [0, 1, 2], replicas=1)
    before = t.degraded_placements
    h3 = t.plan_swap_out(8, [0, 1], replicas=2)
    assert len(h3.placements) == 1  # second leg didn't fit anywhere
    assert t.degraded_placements == before + 1
    tier.check_tier(t)
    assert "tier_quorum_restores" in t.stats()


# --------------------------------------------------------------------------- #
# elastic membership: spare ranks in the role map
# --------------------------------------------------------------------------- #
def test_serve_roles_spares_and_promotion():
    roles = mesh.serve_roles(1, 2, n_memory=1, n_spare=2)
    assert roles == ("prefill", "decode", "decode", "memory", "spare", "spare")
    # spares default to the decode engine (their promotion target)
    backends = mesh.role_backends(roles, decode="gascore")
    assert backends[4] == backends[5] == "gascore"
    assert mesh.role_backends(roles, spare="xla")[4] == "xla"
    promoted = mesh.promote_spare(roles, 4)
    assert promoted[4] == "decode"
    assert len(promoted) == len(roles)
    assert promoted[:4] == roles[:4] and promoted[5] == "spare"
    with pytest.raises(ValueError):
        mesh.promote_spare(roles, 1)  # live pool members never change role
    with pytest.raises(ValueError):
        mesh.promote_spare(roles, 9)  # outside the ring
    with pytest.raises(ValueError):
        mesh.promote_spare(roles, 4, to="spare")


# --------------------------------------------------------------------------- #
# prefix-index migration between pool shards (elastic scale-out)
# --------------------------------------------------------------------------- #
def _smoke_layout():
    from repro.configs.registry import SMOKE
    from repro.models.build import build_model
    from repro.parallel.ctx import RunCtx

    model = build_model(SMOKE["qwen3-4b"])
    ctx = RunCtx(mesh=None, remat="none")
    return pool.PagedLayout.from_struct(
        model.kv_block_struct(ctx, prompt_len=4, cache_len=32),
        cache_len=32,
        page_tokens=8,
    )


def test_prefix_migration_adopt_pin_and_release():
    layout = _smoke_layout()
    donor = pool.PagedKVStore(layout, 8)
    target = pool.PagedKVStore(layout, 8)
    rng = np.random.default_rng(0)
    pages = rng.normal(size=(layout.n_pages, layout.page_elems)).astype(
        np.float32
    )
    shared = list(range(100, 117))  # 2 full pages + a partial third
    donor.admit(1, shared, pages)
    donor.admit(2, shared + [7], pages)
    # the 2 full prefix pages are multiply referenced — the replication
    # policy's "worth replicating" signal
    assert donor.shared_page_count(1) == 2
    entries = donor.prefix_entries()
    assert len(entries) == 2
    assert len(entries[0][0]) < len(entries[1][0])  # shortest chain first
    # target adopts the index: one local page per chain, transfer pairs
    pairs = target.adopt_prefix(entries)
    assert len(pairs) == 2
    assert target.adopt_prefix(entries) == []  # already present
    assert target.stats()["prefix_cache_pages"] == 2
    # donor pins the transfer set: releasing every owner keeps the bytes
    donor.pin_pages([dp for dp, _ in pairs])
    donor.release(1)
    donor.release(2)
    for dp, _ in pairs:
        assert donor.state.refcnt[dp] > 0
    pool.check_pool(donor.state, tables=list(donor.tables.values()))
    donor.unpin_pages()
    assert donor.n_free == 8
    # an admit on the target maps (not moves) the adopted pages
    plan = target.admit(3, shared + [9], pages)
    assert not plan.fresh[0] and not plan.fresh[1]
    target.release(3)
    # dropping the cache returns the pool to empty
    assert target.release_prefix_cache() == 2
    assert target.n_free == 8
    pool.check_pool(target.state)


def test_pool_swap_replica_bookkeeping():
    layout = _smoke_layout()
    store = pool.PagedKVStore(layout, 4)
    store.note_swap_out(5, 3, replicas=1)
    assert store.stats()["swap_out_replica_pages"] == 3
    assert store.swapped_replicated[5] == 1
    store.note_swap_in(5)
    assert 5 not in store.swapped_replicated
    store.note_swap_in(99)  # unknown rids are a no-op
    store.note_swap_out(6, 2, replicas=0)  # unreplicated: bookkeeping-free
    assert 6 not in store.swapped_replicated


# --------------------------------------------------------------------------- #
# tick-clocked heartbeat (the serving control plane's failure detector)
# --------------------------------------------------------------------------- #
def test_heartbeat_monitor_on_a_tick_clock():
    tick = {"now": 0.0}
    m = HeartbeatMonitor([0, 1, 2], timeout_s=3.0, clock=lambda: tick["now"])
    for now in (1.0, 2.0, 3.0):
        tick["now"] = now
        m.beat(0)
        m.beat(1)
        # rank 2 never beats: at exactly timeout ticks it is still alive
        assert m.check() == []
    tick["now"] = 4.0
    m.beat(0)
    m.beat(1)
    assert m.check() == [2]  # strictly MORE than timeout missed ticks
    assert m.failed == [2] and m.alive == [0, 1]
    m.beat(2)  # beats from a declared-dead rank are ignored
    assert m.failed == [2]
    m.admit(2)  # elastic re-admission resets its clock
    assert m.failed == [] and m.check() == []


# --------------------------------------------------------------------------- #
# end-to-end: the deterministic fault-injection suite
# --------------------------------------------------------------------------- #
@pytest.mark.slow
def test_fault_suite_end_to_end(suite_runner):
    out = suite_runner("repro.testing.fault_suite", devices=6)
    # a decode rank dies AFTER the KV put launched but BEFORE the ack —
    # the request re-routes and finishes bit-exact
    assert "kill-decode OK" in out
    assert "died mid-handoff" in out
    # a memory rank dies holding swap legs — the replica leg restores
    assert "quorum-restore OK" in out
    # a spare promotes mid-flight and serves with a migrated prefix index
    assert "elastic-join OK" in out
    # missed-but-within-timeout beats declare nothing dead
    assert "heartbeat-delay OK" in out
    assert "chaos OK" in out
    assert "FAULT_SUITE_PASS" in out

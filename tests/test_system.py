"""End-to-end behaviour of the paper's system: a heterogeneous application
where software nodes and a hardware (GAScore/Pallas) node cooperate through
the unified GAS API — the migration story of §II of the paper — plus the
serving path.

The multi-device end-to-end lives in repro.testing suites (see
test_multidev.py); here we validate the single-device-visible behaviour.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import SMOKE
from repro.models.build import build_model
from repro.parallel.ctx import RunCtx


def test_software_hardware_kernel_migration():
    """ops.* impl switch: verified software path == hardware kernel path."""
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 4, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 128, 64)), jnp.float32)
    sw = ops.attention(q, k, v, impl="ref")
    hw = ops.attention(q, k, v, impl="pallas")
    np.testing.assert_allclose(np.asarray(sw), np.asarray(hw), atol=2e-5,
                               rtol=2e-5)


def test_model_level_migration_is_transparent():
    """The same params produce the same loss under software or hardware
    scan implementations (falcon-mamba: ref lax.scan vs Pallas kernel)."""
    import dataclasses

    cfg = dataclasses.replace(
        SMOKE["falcon-mamba-7b"], d_inner=256, n_layers=2
    )
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    ctx_sw = RunCtx(mesh=None, remat="none", scan_impl="ref")
    ctx_hw = RunCtx(mesh=None, remat="none", scan_impl="pallas")
    params, _ = model.init(ctx_sw, key)
    toks = jax.random.randint(key, (2, 64), 0, cfg.vocab)
    batch = {"inputs": toks, "targets": toks,
             "mask": jnp.ones((2, 64), jnp.float32)}
    l_sw = float(model.train_loss(params, ctx_sw, batch))
    l_hw = float(model.train_loss(params, ctx_hw, batch))
    assert abs(l_sw - l_hw) < 1e-3, (l_sw, l_hw)


def test_serving_continuous_batching():
    from repro.launch.serve import Request, Server

    cfg = SMOKE["qwen3-4b"]
    model = build_model(cfg)
    ctx = RunCtx(mesh=None, remat="none")
    params, _ = model.init(ctx, jax.random.PRNGKey(0))
    server = Server(model, ctx, params, batch_size=3, cache_len=48)
    rng = np.random.default_rng(0)
    for rid in range(7):
        server.submit(Request(
            rid=rid, prompt=rng.integers(0, cfg.vocab, size=8).tolist(),
            max_new=6,
        ))
    stats = server.run_until_drained()
    assert stats["requests"] == 7
    assert stats["decoded_tokens"] >= 7 * 5
    # all requests produced max_new tokens (no EOS in synthetic vocab)
    assert all(len(r.out) == 6 for r in server.finished)


def test_greedy_decode_is_deterministic():
    from repro.launch.serve import Request, Server

    cfg = SMOKE["gemma3-27b"]
    model = build_model(cfg)
    ctx = RunCtx(mesh=None, remat="none")
    params, _ = model.init(ctx, jax.random.PRNGKey(1))

    def gen():
        server = Server(model, ctx, params, batch_size=2, cache_len=32)
        server.submit(Request(rid=0, prompt=[5, 7, 11, 13], max_new=8))
        server.run_until_drained()
        return server.finished[0].out

    assert gen() == gen()

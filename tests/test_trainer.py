"""Training-loop integration: convergence, checkpoint/restart equivalence."""
import tempfile

import jax
import numpy as np

from repro.configs.registry import SMOKE
from repro.data.synthetic import ShardedLoader, SyntheticLM
from repro.models.build import build_model
from repro.optim import adamw
from repro.parallel.ctx import RunCtx
from repro.runtime.trainer import Trainer, TrainerConfig

CFG = SMOKE["qwen3-4b"]
CTX = RunCtx(mesh=None, remat="none")
OPT = adamw.AdamWConfig(lr=3e-3, weight_decay=0.0)


def _run(steps, ckpt_dir=None, ckpt_every=0, start=0, resume=False, seed=0):
    model = build_model(CFG)
    tr = Trainer(model, CTX, OPT, TrainerConfig(
        steps=steps, ckpt_every=ckpt_every, ckpt_dir=ckpt_dir, log_every=5))
    key = jax.random.PRNGKey(seed)
    if resume:
        params, st, start, extra = tr.recover(key)
        data_start = int(extra.get("data_step", start))
    else:
        params, st = tr.init(key)
        data_start = start
    src = SyntheticLM(CFG, batch=16, seq_len=64, seed=1)
    loader = ShardedLoader(src, start_step=data_start)
    try:
        params, st, hist = tr.run(params, st, loader, start_step=start)
    finally:
        loader.close()
    return params, hist


def test_loss_decreases():
    _, hist = _run(steps=60)
    assert hist[-1]["loss"] < hist[0]["loss"] - 1.0


def test_restart_bitwise_equivalence():
    """interrupted-and-restarted == uninterrupted (same mesh, same data)."""
    with tempfile.TemporaryDirectory() as td:
        pA, _ = _run(steps=20, ckpt_dir=td, ckpt_every=10)
        # fresh process state: restore at 20 happened; emulate crash at 10:
        # wipe later ckpt so restore picks step 10, then rerun to 20
        from repro.checkpoint import ckpt as CK
        import shutil, os

        for d in os.listdir(td):
            if d.startswith("step_") and int(d.split("_")[1]) > 10:
                shutil.rmtree(os.path.join(td, d))
        assert CK.latest_step(td) == 10
        pB, _ = _run(steps=20, ckpt_dir=td, resume=True, seed=123)
        for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pB)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grad_accumulation_matches_large_batch():
    """ga=2 over batch 16 == single step over batch 16 (same tokens)."""
    model = build_model(CFG)
    src = SyntheticLM(CFG, batch=16, seq_len=32, seed=3)
    batch = {k: jax.numpy.asarray(v) for k, v in src.batch_at(0).items()}
    key = jax.random.PRNGKey(0)

    def one(ga):
        tr = Trainer(model, CTX, OPT, TrainerConfig(steps=1, ga_steps=ga,
                                                    ckpt_every=0))
        params, st = tr.init(key)
        fn = tr.make_train_step()
        p2, _, m = fn(params, st, batch)
        return p2, m

    pa, ma = one(1)
    pb, mb = one(2)
    assert abs(ma["loss"] - mb["loss"]) < 1e-4
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        # f32 reduction-order noise through AdamW's rsqrt: loose atol
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-4)
